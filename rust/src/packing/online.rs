//! Online (streaming) BLoad — windowed block packing over an unbounded
//! sequence stream. This is the BLoad strategy's streaming mode: the
//! [`crate::ingest`] service obtains it through the registry as
//! `by_name("bload").streaming(ctx)` (a boxed
//! [`StreamPacker`](super::StreamPacker)), not as a separate code path.
//!
//! The paper's Fig 7 algorithm materializes the full length dictionary
//! `L_dict` before packing an epoch. That rules out streaming ingest, where
//! sequences arrive continuously from many producers and no one ever holds
//! the whole dataset. [`OnlinePacker`] runs the *same* inner loop — the
//! uniform `Random*` draw over every candidate that still fits the open
//! block, via the exact [`LengthDict`] used offline — but over a **sliding
//! candidate pool** of at most `W` pending sequences:
//!
//! ```text
//! on arrival(s):  pool.insert(s)
//!                 while some candidate fits open block: place Random*(pool)
//!                 while |pool| > W: flush open block  (pool-full watermark)
//! on tick:        age open block; flush when age ≥ max_latency
//! on end-of-stream: drain pool exactly like offline BLoad
//! ```
//!
//! Flush policies bound per-block padding:
//!
//! * **pool-full** — a block only closes when nothing in a full window
//!   fits, so its padding is `< min(pending lengths)` at close time —
//!   the same invariant the offline packer guarantees via
//!   `remaining < min(keys(L_dict))`.
//! * **max-latency** — with `max_latency = L > 0`, an open block is
//!   force-flushed after `L` ticks (one tick per arrival interval is the
//!   intended clock), trading padding for bounded block latency.
//! * **end-of-stream** — [`OnlinePacker::finish`] drains the pool with the
//!   offline loop; the tail degrades gracefully to offline BLoad over the
//!   last `≤ W` sequences.
//!
//! Structural guarantee used by the padding-ratio property tests: a block
//! is only ever emitted with at least one placement, so the packer emits at
//! most one block per sequence and its padding ratio can never exceed the
//! naive one-block-per-sequence strategy's.

use crate::error::{Error, Result};
use crate::util::Rng;

use super::bload::LengthDict;
use super::Block;

/// Knobs of the windowed online packer.
#[derive(Debug, Clone, Copy)]
pub struct OnlineConfig {
    /// Uniform output block length (the executable's `T`); every sequence
    /// must satisfy `len ≤ t_max`.
    pub t_max: usize,
    /// Sliding-window watermark `W`: the candidate pool never holds more
    /// than `W` pending sequences after a push returns.
    pub window: usize,
    /// Force-flush an open block after this many ticks (0 = no latency
    /// flush; blocks close only on pool-full or end-of-stream).
    pub max_latency: usize,
}

impl OnlineConfig {
    /// Defaults tuned for the AG-Synth distribution: window 64, no
    /// latency flush.
    pub fn new(t_max: usize) -> OnlineConfig {
        OnlineConfig {
            t_max,
            window: 64,
            max_latency: 0,
        }
    }

    fn validate(&self) -> Result<()> {
        if self.t_max == 0 {
            return Err(Error::Packing("online: t_max must be >= 1".into()));
        }
        if self.window == 0 {
            return Err(Error::Packing("online: window must be >= 1".into()));
        }
        Ok(())
    }
}

/// Why a block was flushed (counted in [`OnlineStats`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum FlushReason {
    PoolFull,
    Latency,
    EndOfStream,
}

/// Running accounting of an online packing session.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct OnlineStats {
    /// Sequences accepted by [`OnlinePacker::push`].
    pub received: usize,
    /// Sequences placed into emitted blocks (the rest are still pending).
    pub placed: usize,
    /// Blocks emitted so far.
    pub blocks: usize,
    /// Slots across emitted blocks (`blocks * t_max`).
    pub total_slots: usize,
    /// Padding slots across emitted blocks.
    pub padding: usize,
    /// Real frames across emitted blocks.
    pub frames: usize,
    /// Blocks flushed because the pool exceeded the window watermark.
    pub flush_pool_full: usize,
    /// Blocks flushed by the max-latency clock.
    pub flush_latency: usize,
    /// Blocks flushed while draining at end-of-stream.
    pub flush_eos: usize,
}

impl OnlineStats {
    /// Padding fraction of emitted slots (0 when nothing was emitted).
    pub fn padding_ratio(&self) -> f64 {
        if self.total_slots == 0 {
            0.0
        } else {
            self.padding as f64 / self.total_slots as f64
        }
    }
}

/// Streaming BLoad packer over a sliding candidate pool.
///
/// Feed arrivals with [`push`](OnlinePacker::push), advance the latency
/// clock with [`tick`](OnlinePacker::tick), and drain the tail with
/// [`finish`](OnlinePacker::finish); each call returns the blocks completed
/// by that event. Deterministic in `(seed, arrival order)`.
#[derive(Debug)]
pub struct OnlinePacker {
    cfg: OnlineConfig,
    rng: Rng,
    /// Sliding candidate pool (the streaming slice of the paper's L_dict).
    pool: LengthDict,
    open: Block,
    remaining: usize,
    open_age: usize,
    stats: OnlineStats,
}

impl OnlinePacker {
    pub fn new(cfg: OnlineConfig, seed: u64) -> Result<OnlinePacker> {
        cfg.validate()?;
        Ok(OnlinePacker {
            cfg,
            // Same seed whitening as the offline entry point so the two
            // paths draw from comparable streams.
            rng: Rng::new(seed ^ 0xB10C),
            pool: LengthDict::new(),
            open: Block::new(cfg.t_max),
            remaining: cfg.t_max,
            open_age: 0,
            stats: OnlineStats::default(),
        })
    }

    /// Sequences pending in the pool (not yet placed in an emitted or the
    /// open block).
    pub fn pending(&self) -> usize {
        self.pool.len()
    }

    /// Sequences placed in the *open* (unemitted) block.
    pub fn open_segments(&self) -> usize {
        self.open.segments.len()
    }

    pub fn stats(&self) -> &OnlineStats {
        &self.stats
    }

    /// Offer one sequence to the packer. Returns every block the arrival
    /// completed (possibly none). `id`s must be unique across the stream;
    /// duplicates are caught downstream by `validate_stream`.
    pub fn push(&mut self, id: u32, len: usize) -> Result<Vec<Block>> {
        if len == 0 {
            return Err(Error::Packing(format!(
                "online: sequence {id} has zero length"
            )));
        }
        if len > self.cfg.t_max {
            return Err(Error::Packing(format!(
                "online: sequence {id} of length {len} exceeds t_max {}; \
                 the paper requires T_i <= T_max for all i",
                self.cfg.t_max
            )));
        }
        self.pool.insert(id, len);
        self.stats.received += 1;
        let mut out = Vec::new();
        self.fill_open();
        // Pool-full watermark: keep flushing until the pool fits the
        // window again. Each iteration places at least one sequence (a
        // fresh block accepts any len ≤ t_max), so this terminates.
        while self.pool.len() > self.cfg.window {
            self.close_open(&mut out, FlushReason::PoolFull);
            self.fill_open();
        }
        Ok(out)
    }

    /// Advance the latency clock one tick (callers tick once per arrival
    /// interval). Returns the flushed block when the open block's age
    /// reaches `max_latency`.
    pub fn tick(&mut self) -> Vec<Block> {
        let mut out = Vec::new();
        if self.cfg.max_latency > 0 && !self.open.segments.is_empty() {
            self.open_age += 1;
            if self.open_age >= self.cfg.max_latency {
                self.fill_open();
                self.close_open(&mut out, FlushReason::Latency);
            }
        }
        out
    }

    /// End-of-stream: drain the pool exactly like the offline packer
    /// (repeated fill/close cycles), returning the tail blocks and the
    /// final stats.
    pub fn finish(mut self) -> (Vec<Block>, OnlineStats) {
        let mut out = Vec::new();
        loop {
            self.fill_open();
            if self.pool.is_empty() {
                break;
            }
            self.close_open(&mut out, FlushReason::EndOfStream);
        }
        self.close_open(&mut out, FlushReason::EndOfStream);
        (out, self.stats)
    }

    /// Fig 7's inner loop over the pool: place uniform draws over fitting
    /// candidates until nothing pending fits the open block.
    fn fill_open(&mut self) {
        while let Some(min) = self.pool.min_len() {
            if self.remaining < min {
                break;
            }
            let (id, len) = self
                .pool
                .draw_fitting(self.remaining, &mut self.rng)
                .expect("min fits, so at least one candidate is eligible");
            self.open
                .push(id, 0, len)
                .expect("draw_fitting only returns fitting lengths");
            self.remaining -= len;
            self.stats.placed += 1;
            self.stats.frames += len;
        }
    }

    /// Emit the open block (no-op while it is empty — the packer never
    /// emits all-padding blocks, which is what bounds the padding ratio).
    fn close_open(&mut self, out: &mut Vec<Block>, reason: FlushReason) {
        if self.open.segments.is_empty() {
            return;
        }
        self.stats.blocks += 1;
        self.stats.total_slots += self.cfg.t_max;
        self.stats.padding += self.remaining;
        match reason {
            FlushReason::PoolFull => self.stats.flush_pool_full += 1,
            FlushReason::Latency => self.stats.flush_latency += 1,
            FlushReason::EndOfStream => self.stats.flush_eos += 1,
        }
        let block = std::mem::replace(&mut self.open,
                                      Block::new(self.cfg.t_max));
        out.push(block);
        self.remaining = self.cfg.t_max;
        self.open_age = 0;
    }
}

/// [`OnlinePacker`] is the BLoad strategy's [`super::StreamPacker`]: the
/// trait surface the ingest service drives, forwarding to the inherent
/// methods above.
impl super::StreamPacker for OnlinePacker {
    fn push(&mut self, id: u32, len: usize) -> Result<Vec<Block>> {
        OnlinePacker::push(self, id, len)
    }

    fn tick(&mut self) -> Vec<Block> {
        OnlinePacker::tick(self)
    }

    fn pending(&self) -> usize {
        OnlinePacker::pending(self)
    }

    fn stats(&self) -> &OnlineStats {
        OnlinePacker::stats(self)
    }

    fn finish(self: Box<Self>) -> (Vec<Block>, OnlineStats) {
        OnlinePacker::finish(*self)
    }
}

/// Convenience: run a whole metadata stream through an [`OnlinePacker`]
/// with one tick per arrival, returning all blocks and the final stats.
pub fn pack_stream<I>(items: I, cfg: OnlineConfig, seed: u64)
                      -> Result<(Vec<Block>, OnlineStats)>
where
    I: IntoIterator<Item = (u32, usize)>,
{
    let mut packer = OnlinePacker::new(cfg, seed)?;
    let mut blocks = Vec::new();
    for (id, len) in items {
        blocks.extend(packer.push(id, len)?);
        blocks.extend(packer.tick());
    }
    let (tail, stats) = packer.finish();
    blocks.extend(tail);
    Ok((blocks, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::dataset::synthetic::{generate, tiny_config};
    use crate::dataset::Split;
    use crate::packing::validate::validate_stream;

    fn arrivals(split: &Split) -> Vec<(u32, usize)> {
        split
            .videos
            .iter()
            .map(|v| (v.id, v.len as usize))
            .collect()
    }

    /// padding_ratio(online) ≤ padding_ratio(naive), cross-multiplied to
    /// stay in integers.
    fn assert_ratio_at_most_naive(stats: &OnlineStats, n: usize,
                                  t_max: usize, frames: usize) {
        let naive_padding = n * t_max - frames;
        let naive_slots = n * t_max;
        assert!(
            stats.padding * naive_slots <= naive_padding * stats.total_slots
                || stats.padding == 0,
            "online ratio {} > naive ratio {}",
            stats.padding_ratio(),
            naive_padding as f64 / naive_slots as f64
        );
    }

    #[test]
    fn property_every_sequence_placed_exactly_once() {
        // For any arrival order, window size and latency policy: every
        // sequence lands in exactly one block, blocks respect T_max, and
        // the padding ratio never exceeds naive padding.
        let cfg = ExperimentConfig::default_config().dataset.scaled(0.03);
        let ds = generate(&cfg, 13);
        let mut order = arrivals(&ds.train);
        let frames = ds.train.total_frames();
        let n = order.len();
        let mut rng = crate::util::Rng::new(99);
        for (case, &window) in
            [1usize, 2, 5, 16, 64, 4096].iter().enumerate()
        {
            rng.shuffle(&mut order);
            for max_latency in [0usize, 3] {
                let ocfg = OnlineConfig { t_max: 94, window, max_latency };
                let (blocks, stats) =
                    pack_stream(order.iter().copied(), ocfg, case as u64)
                        .unwrap();
                for b in &blocks {
                    assert_eq!(b.len, 94);
                    assert!(!b.segments.is_empty(), "empty block emitted");
                    assert!(b.used() <= 94);
                }
                // Exactly-once + contiguity + full coverage.
                let summary =
                    validate_stream(blocks.iter(), &ds.train, 94)
                        .unwrap_or_else(|e| {
                            panic!("W={window} L={max_latency}: {e}")
                        });
                assert_eq!(summary.frames_placed, frames);
                assert_eq!(summary.videos_placed, n);
                assert_eq!(stats.placed, n);
                assert_eq!(stats.received, n);
                assert_ratio_at_most_naive(&stats, n, 94, frames);
            }
        }
    }

    #[test]
    fn pool_full_flush_bounds_padding_like_offline() {
        // Blocks closed by the pool-full watermark satisfy the offline
        // close condition: padding < the shortest sequence still pending
        // at close time. Weaker global check (same as the offline test):
        // padding of each non-tail block < global min length, or every
        // later-placed sequence is longer than that padding.
        let cfg = ExperimentConfig::default_config().dataset.scaled(0.05);
        let ds = generate(&cfg, 11);
        let min_len = ds.train.min_len();
        let ocfg = OnlineConfig { t_max: 94, window: 64, max_latency: 0 };
        let (blocks, stats) =
            pack_stream(arrivals(&ds.train), ocfg, 1).unwrap();
        assert!(stats.flush_pool_full > 0, "watermark never hit");
        for (i, b) in blocks.iter().enumerate() {
            if i + 1 < blocks.len() {
                assert!(
                    b.padding() < min_len
                        || blocks[i + 1..]
                            .iter()
                            .flat_map(|nb| nb.segments.iter())
                            .all(|s| s.len > b.padding()),
                    "block {i} closed with {} free while a shorter \
                     sequence was pending",
                    b.padding()
                );
            }
        }
    }

    #[test]
    fn latency_one_degenerates_to_naive() {
        // max_latency = 1 flushes after every arrival: one sequence per
        // block, i.e. exactly the naive strategy's padding.
        let ds = generate(&tiny_config(), 3);
        let ocfg = OnlineConfig { t_max: 6, window: 4096, max_latency: 1 };
        let (blocks, stats) =
            pack_stream(arrivals(&ds.train), ocfg, 0).unwrap();
        assert_eq!(blocks.len(), ds.train.videos.len());
        assert!(blocks.iter().all(|b| b.segments.len() == 1));
        assert_eq!(
            stats.padding,
            ds.train.videos.len() * 6 - ds.train.total_frames()
        );
        assert_eq!(stats.flush_latency + stats.flush_eos, stats.blocks);
    }

    #[test]
    fn window_bounds_pending_pool() {
        let cfg = ExperimentConfig::default_config().dataset.scaled(0.02);
        let ds = generate(&cfg, 7);
        for window in [1usize, 3, 17] {
            let ocfg = OnlineConfig { t_max: 94, window, max_latency: 0 };
            let mut p = OnlinePacker::new(ocfg, 0).unwrap();
            for (id, len) in arrivals(&ds.train) {
                p.push(id, len).unwrap();
                assert!(
                    p.pending() <= window,
                    "pool {} exceeds window {window}",
                    p.pending()
                );
            }
        }
    }

    #[test]
    fn deterministic_in_seed_and_order() {
        let cfg = ExperimentConfig::default_config().dataset.scaled(0.02);
        let ds = generate(&cfg, 2);
        let ocfg = OnlineConfig { t_max: 94, window: 32, max_latency: 2 };
        let run = |seed: u64| {
            pack_stream(arrivals(&ds.train), ocfg, seed).unwrap().0
        };
        assert_eq!(run(4), run(4));
        assert_ne!(run(4), run(5), "different seed, different packing");
    }

    #[test]
    fn rejects_oversized_and_empty_sequences() {
        let mut p =
            OnlinePacker::new(OnlineConfig::new(10), 0).unwrap();
        assert!(p.push(1, 11).is_err());
        assert!(p.push(2, 0).is_err());
        assert!(p.push(3, 10).is_ok());
        assert!(OnlinePacker::new(
            OnlineConfig { t_max: 10, window: 0, max_latency: 0 },
            0
        )
        .is_err());
    }

    #[test]
    fn large_window_approaches_offline_padding() {
        // With the window larger than the dataset, finish() IS the offline
        // algorithm; padding must be far below naive (the paper's >50×
        // reduction at this scale).
        let cfg = ExperimentConfig::default_config().dataset.scaled(0.2);
        let ds = generate(&cfg, 2);
        let ocfg =
            OnlineConfig { t_max: 94, window: usize::MAX / 2, max_latency: 0 };
        let (_, stats) = pack_stream(arrivals(&ds.train), ocfg, 3).unwrap();
        let naive_padding =
            ds.train.videos.len() * 94 - ds.train.total_frames();
        assert!(
            stats.padding * 50 < naive_padding,
            "online {} vs naive {naive_padding}",
            stats.padding
        );
    }
}
