//! `sampling` baseline (paper Fig 4, the MOTR/TrackFormer-style chunking):
//! every video is cut into fixed `t_block`-frame chunks; remainder frames
//! (and whole videos shorter than `t_block`) are **deleted**. Chunks of
//! one video become *independent* samples — the temporal relationship
//! across chunk boundaries is destroyed, which is why recurrent models
//! like DDS lose recall under this strategy (Table I: 41.2 vs 43.3).
//!
//! On Action Genome geometry with `t_block = 24 ≈ mean length` this
//! deletes ≈ 92 k of 167 k frames — the paper's "discarding nearly 2/3 of
//! the data".

use crate::config::PackingConfig;
use crate::dataset::Split;
use crate::error::{Error, Result};
use crate::util::Rng;

use super::{Block, PackContext, PackedDataset, Packer};

/// Registry entry for the `sampling` (chunking) strategy.
#[derive(Debug)]
pub struct Sampling;

impl Packer for Sampling {
    fn name(&self) -> &'static str {
        "sampling"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["chunk", "chunking"]
    }

    fn label(&self) -> &'static str {
        "sampling"
    }

    fn describe(&self) -> &'static str {
        "fixed t_block chunks, remainders deleted (paper Fig 4)"
    }

    fn native_block_len(&self, cfg: &PackingConfig) -> usize {
        cfg.t_block
    }

    fn pack(&self, split: &Split, ctx: &PackContext)
            -> Result<PackedDataset> {
        let mut rng = ctx.rng();
        pack(split, ctx.t_block, ctx.block_len, &mut rng)
    }
}

/// Chunk into `t_block` pieces, group whole chunks into blocks of
/// `block_len` slots (`block_len % t_block == 0`; pass `block_len ==
/// t_block` for the paper's one-chunk-per-sample accounting), shuffle
/// chunk order.
pub fn pack(split: &Split, t_block: usize, block_len: usize, rng: &mut Rng)
            -> Result<PackedDataset> {
    if t_block == 0 || block_len < t_block || block_len % t_block != 0 {
        return Err(Error::Packing(format!(
            "sampling: block_len {block_len} must be a positive multiple of \
             t_block {t_block}"
        )));
    }
    // Enumerate full chunks; remainders are deleted by never placing them.
    let mut chunks: Vec<(u32, usize)> = Vec::new(); // (video, src_start)
    for v in &split.videos {
        let n = v.len as usize / t_block;
        for c in 0..n {
            chunks.push((v.id, c * t_block));
        }
    }
    rng.shuffle(&mut chunks);

    let per_block = block_len / t_block;
    let mut blocks = Vec::with_capacity(chunks.len().div_ceil(per_block));
    for group in chunks.chunks(per_block) {
        let mut b = Block::new(block_len);
        for &(video, src_start) in group {
            b.push(video, src_start, t_block)?;
        }
        blocks.push(b);
    }
    Ok(PackedDataset::finalize("sampling", block_len, blocks, split))
}

/// Ordered, merge-contiguous variant — the **stateful chunking** extension
/// (the paper's §V future work, benchmarked by `harness::ablation`):
/// chunks are laid out in video order and contiguous same-video chunks in
/// one block are merged into a single segment, so
/// (a) within a block the reset table does not sever a video's context and
/// (b) across blocks the trainer's [`crate::model::StateManager`] can hand
/// the feedback state to the next chunk.
pub fn pack_ordered(split: &Split, t_block: usize, block_len: usize)
                    -> Result<PackedDataset> {
    if t_block == 0 || block_len < t_block || block_len % t_block != 0 {
        return Err(Error::Packing(format!(
            "sampling: block_len {block_len} must be a positive multiple of \
             t_block {t_block}"
        )));
    }
    let per_block = block_len / t_block;
    let mut blocks: Vec<Block> = Vec::new();
    let mut cur = Block::new(block_len);
    let mut used_chunks = 0usize;
    for v in &split.videos {
        let n = v.len as usize / t_block;
        for c in 0..n {
            if used_chunks == per_block {
                blocks.push(std::mem::replace(&mut cur,
                                              Block::new(block_len)));
                used_chunks = 0;
            }
            let src_start = c * t_block;
            // Merge into the previous segment when it is the same video
            // and frame-contiguous.
            if let Some(last) = cur.segments.last_mut() {
                if last.video == v.id
                    && last.src_start + last.len == src_start
                {
                    last.len += t_block;
                    used_chunks += 1;
                    continue;
                }
            }
            cur.push(v.id, src_start, t_block)?;
            used_chunks += 1;
        }
    }
    if used_chunks > 0 {
        blocks.push(cur);
    }
    Ok(PackedDataset::finalize("sampling_ordered", block_len, blocks,
                               split))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::dataset::synthetic::generate;
    use crate::util::Rng;

    #[test]
    fn deletion_accounting_matches_paper_scale() {
        // Paper Table I: 92,271 deleted. Chunk-to-24 on the calibrated
        // distribution lands within a few percent (DESIGN.md §4).
        let cfg = ExperimentConfig::default_config().dataset;
        let ds = generate(&cfg, 0);
        let packed = pack(&ds.train, 24, 24, &mut Rng::new(1)).unwrap();
        let expect: usize = ds
            .train
            .videos
            .iter()
            .map(|v| v.len as usize % 24)
            .sum();
        assert_eq!(packed.stats.frames_deleted, expect);
        let rel = (packed.stats.frames_deleted as f64 - 92_271.0).abs()
            / 92_271.0;
        assert!(rel < 0.08, "deleted {} vs paper 92271",
                packed.stats.frames_deleted);
        // Zero padding: every chunk fills its slots exactly.
        assert_eq!(packed.stats.padding, 0);
    }

    #[test]
    fn videos_are_fragmented() {
        let cfg = ExperimentConfig::default_config().dataset.scaled(0.05);
        let ds = generate(&cfg, 2);
        let packed = pack(&ds.train, 10, 10, &mut Rng::new(1)).unwrap();
        assert!(
            packed.stats.fragmented_videos > 0,
            "long videos must split into several chunks"
        );
        // All placements are exactly t_block long and offset-aligned.
        for b in &packed.blocks {
            for s in &b.segments {
                assert_eq!(s.len, 10);
                assert_eq!(s.src_start % 10, 0);
            }
        }
    }

    #[test]
    fn grouping_into_wider_blocks() {
        let cfg = ExperimentConfig::default_config().dataset.scaled(0.02);
        let ds = generate(&cfg, 3);
        let packed = pack(&ds.train, 8, 24, &mut Rng::new(4)).unwrap();
        for b in &packed.blocks[..packed.blocks.len() - 1] {
            assert_eq!(b.segments.len(), 3, "3 chunks of 8 per 24-block");
            assert_eq!(b.padding(), 0);
        }
        // Chunks inside one block are separate segments (ids differ) even
        // when they come from the same video: temporal link is broken.
        let b0 = &packed.blocks[0];
        let ids = b0.seg_ids();
        assert_eq!(ids[0], 0);
        assert_eq!(ids[8], 1);
        assert_eq!(ids[16], 2);
    }

    #[test]
    fn rejects_nondivisible_grouping() {
        let cfg = ExperimentConfig::default_config().dataset.scaled(0.01);
        let ds = generate(&cfg, 3);
        assert!(pack(&ds.train, 10, 25, &mut Rng::new(0)).is_err());
        assert!(pack(&ds.train, 10, 5, &mut Rng::new(0)).is_err());
    }

    #[test]
    fn ordered_variant_merges_contiguous_chunks() {
        let cfg = ExperimentConfig::default_config().dataset.scaled(0.02);
        let ds = generate(&cfg, 3);
        let packed = pack_ordered(&ds.train, 8, 24).unwrap();
        crate::packing::validate::validate(&packed, &ds.train, false)
            .unwrap();
        // Same deletion accounting as the shuffled variant.
        let shuffled = pack(&ds.train, 8, 24, &mut Rng::new(0)).unwrap();
        assert_eq!(packed.stats.frames_deleted,
                   shuffled.stats.frames_deleted);
        assert_eq!(packed.stats.frames_kept, shuffled.stats.frames_kept);
        // A 24-frame-or-longer video yields one merged 24-slot segment.
        let long = ds.train.videos.iter().find(|v| v.len >= 24).unwrap();
        let merged = packed
            .blocks
            .iter()
            .flat_map(|b| b.segments.iter())
            .find(|s| s.video == long.id && s.len == 24);
        assert!(merged.is_some(), "expected a merged full-block segment");
        // Fewer fragments than the shuffled variant (context preserved).
        assert!(packed.stats.fragmented_videos
                <= shuffled.stats.fragmented_videos);
    }

    #[test]
    fn ordered_variant_keeps_cross_block_continuations() {
        // A 40-frame video at t_block 8, block 24: segments [0,24) and
        // [24,40) in consecutive blocks — the StateManager resume key.
        let mut dcfg = crate::harness::scaled_dataset(1, 1, 0.4);
        dcfg.min_len = 40;
        dcfg.max_len = 40;
        dcfg.mean_len = 40.0;
        let ds = generate(&dcfg, 0);
        let packed = pack_ordered(&ds.train, 8, 24).unwrap();
        assert_eq!(packed.blocks.len(), 2);
        let s0 = packed.blocks[0].segments[0];
        let s1 = packed.blocks[1].segments[0];
        assert_eq!((s0.src_start, s0.len), (0, 24));
        assert_eq!((s1.src_start, s1.len), (24, 16));
        assert_eq!(s0.src_start + s0.len, s1.src_start);
    }

    #[test]
    fn short_videos_entirely_deleted() {
        let ds = generate(&crate::dataset::synthetic::tiny_config(), 9);
        // t_block = 7 > max_len 6 => everything deleted, zero blocks.
        let packed = pack(&ds.train, 7, 7, &mut Rng::new(0)).unwrap();
        assert_eq!(packed.stats.frames_kept, 0);
        assert_eq!(packed.stats.frames_deleted, ds.train.total_frames());
        assert_eq!(packed.stats.blocks, 0);
    }
}
