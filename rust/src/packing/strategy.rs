//! The open strategy API: one [`Packer`] trait and a runtime registry.
//!
//! Every packing strategy — the paper's four Table I columns and any
//! later addition — implements [`Packer`] in its own module and appears
//! as exactly one line in [`registry`]. Consumers (`harness::table1`,
//! `harness::streaming`, the CLI, `benches/packing.rs`, the config
//! layer) resolve strategies by string key through the registry instead
//! of matching a closed enum, so landing a new strategy touches only its
//! module plus that one registry line.
//!
//! Offline and streaming packing share the abstraction: a strategy that
//! can pack an unbounded arrival stream (today: BLoad's windowed
//! [`super::online::OnlinePacker`]) exposes it through
//! [`Packer::streaming`] as a [`StreamPacker`], which the
//! [`crate::ingest`] service drives — the online path is the BLoad
//! packer's streaming mode, not a parallel code path.

use crate::config::PackingConfig;
use crate::dataset::Split;
use crate::error::{Error, Result};
use crate::util::Rng;

use super::online::{OnlineConfig, OnlineStats};
use super::{Block, PackedDataset};

/// Everything a strategy needs to pack: geometry knobs (copied out of
/// [`PackingConfig`] so streaming callers need no config document), the
/// uniform output block length, the seed, and the streaming-window knobs
/// used by [`Packer::streaming`] implementations.
#[derive(Debug, Clone)]
pub struct PackContext {
    /// Uniform output block length (the executable's `T`).
    pub block_len: usize,
    /// Chunk length for chunking strategies (`packing.t_block`).
    pub t_block: usize,
    /// Target lane length for mix pad (`packing.t_mix`).
    pub t_mix: usize,
    /// Seed of the strategy's deterministic RNG.
    pub seed: u64,
    /// Sliding-window watermark for streaming modes.
    pub window: usize,
    /// Latency flush in ticks for streaming modes (0 = off).
    pub max_latency: usize,
}

impl PackContext {
    /// Context for offline packing at an explicit block length. The
    /// streaming knobs inherit [`OnlineConfig::new`]'s tuned defaults so
    /// they live in exactly one place.
    pub fn new(cfg: &PackingConfig, block_len: usize, seed: u64)
               -> PackContext {
        let stream_defaults = OnlineConfig::new(block_len);
        PackContext {
            block_len,
            t_block: cfg.t_block,
            t_mix: cfg.t_mix,
            seed,
            window: stream_defaults.window,
            max_latency: stream_defaults.max_latency,
        }
    }

    /// Context for a streaming session (no offline chunk/mix geometry;
    /// those knobs default to `block_len`).
    pub fn streaming(block_len: usize, window: usize, max_latency: usize,
                     seed: u64) -> PackContext {
        PackContext {
            block_len,
            t_block: block_len,
            t_mix: block_len,
            seed,
            window,
            max_latency,
        }
    }

    /// The strategy RNG for this context — the single derivation point
    /// of the `seed ^ 0xB10C` whitening every strategy shares, so
    /// identical seeds keep producing identical layouts across the
    /// registry.
    pub fn rng(&self) -> Rng {
        Rng::new(self.seed ^ 0xB10C)
    }
}

/// One packing strategy, registered in [`registry`].
///
/// Implementations are stateless unit structs; all run state lives in
/// the [`PackContext`] and locals, so a single `&'static` instance
/// serves every caller.
pub trait Packer: Sync + std::fmt::Debug {
    /// Canonical registry key (`--strategy <name>`, `packing.strategy`).
    fn name(&self) -> &'static str;

    /// Accepted spellings besides [`name`](Packer::name) (config
    /// compatibility; matched case-insensitively).
    fn aliases(&self) -> &'static [&'static str] {
        &[]
    }

    /// Column label used in the paper's Table I rendering.
    fn label(&self) -> &'static str;

    /// One-line description with the source citation (shown by
    /// `bload strategies`).
    fn describe(&self) -> &'static str;

    /// The strategy's *native* block length for paper-exact Table I
    /// accounting (`t_max` for whole-video packers, `t_block`/`t_mix`
    /// for the chunking/lane baselines).
    fn native_block_len(&self, cfg: &PackingConfig) -> usize;

    /// Whether placements may extend past their video's last real frame
    /// (within-video padding, validated leniently — mix pad and bucket
    /// lanes).
    fn within_video_padding(&self) -> bool {
        false
    }

    /// Pack a materialized split into uniform `ctx.block_len` blocks.
    fn pack(&self, split: &Split, ctx: &PackContext) -> Result<PackedDataset>;

    /// The strategy's streaming mode over an unbounded arrival stream,
    /// when it has one. `None` means offline-only; `Some(Err)` surfaces
    /// invalid streaming knobs synchronously.
    fn streaming(&self, _ctx: &PackContext)
                 -> Option<Result<Box<dyn StreamPacker>>> {
        None
    }
}

/// Incremental packer over an unbounded sequence stream — the streaming
/// face of a [`Packer`] (see [`Packer::streaming`]), driven by the
/// [`crate::ingest`] service.
///
/// Session accounting uses [`OnlineStats`] for every implementation:
/// its counters (received/placed/blocks/slots/padding plus
/// capacity/latency/end-of-stream flush reasons) describe any bounded
/// streaming packer's lifecycle, not BLoad specifically — a new
/// implementation fills the flush counters for whichever of the three
/// policies it applies. The type lives in [`super::online`] (its first
/// implementor) and is re-consumed by `ingest::IngestStats` unchanged.
pub trait StreamPacker: Send {
    /// Offer one sequence; returns every block the arrival completed.
    fn push(&mut self, id: u32, len: usize) -> Result<Vec<Block>>;

    /// Advance the latency clock one tick; returns any flushed block.
    fn tick(&mut self) -> Vec<Block>;

    /// Sequences pending (accepted but not yet in an emitted block).
    fn pending(&self) -> usize;

    /// Running accounting of the session.
    fn stats(&self) -> &OnlineStats;

    /// End-of-stream: drain everything pending, returning the tail
    /// blocks and the final stats.
    fn finish(self: Box<Self>) -> (Vec<Block>, OnlineStats);
}

/// All registered strategies, Table I columns first, extensions after.
/// Adding a strategy = its module + one line here.
pub fn registry() -> &'static [&'static dyn Packer] {
    static REGISTRY: [&'static dyn Packer; 6] = [
        &super::naive::NaivePad,
        &super::sampling::Sampling,
        &super::mixpad::MixPad,
        &super::bload::BLoad,
        &super::ffd::Ffd,
        &super::bucket::Bucket,
    ];
    &REGISTRY
}

/// Case-insensitive lookup by key, alias, or Table I label.
pub fn lookup(name: &str) -> Option<&'static dyn Packer> {
    let k = name.trim().to_ascii_lowercase();
    registry().iter().copied().find(|p| {
        p.name() == k
            || p.label() == k
            || p.aliases().iter().any(|&a| a == k)
    })
}

/// [`lookup`] that errors with the list of known keys.
pub fn by_name(name: &str) -> Result<&'static dyn Packer> {
    lookup(name).ok_or_else(|| {
        let known: Vec<&str> = registry().iter().map(|p| p.name()).collect();
        Error::Config(format!(
            "unknown packing strategy '{name}' (known: {})",
            known.join("|")
        ))
    })
}

#[cfg(test)]
mod tests {
    use super::super::validate::validate;
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::dataset::synthetic::generate;

    #[test]
    fn registry_keys_unique_and_lookup_resolves_aliases() {
        // Every spelling lookup() accepts — key, label, alias — must
        // resolve to exactly one entry; a cross-entry collision would
        // silently shadow whichever strategy registers later.
        let mut claimed: std::collections::HashMap<String, &str> =
            Default::default();
        for p in registry() {
            let mut mine: Vec<String> =
                vec![p.name().to_string(), p.label().to_string()];
            mine.extend(p.aliases().iter().map(|a| a.to_string()));
            mine.sort_unstable();
            mine.dedup(); // name == label within one entry is fine
            for spelling in mine {
                if let Some(other) =
                    claimed.insert(spelling.clone(), p.name())
                {
                    panic!(
                        "spelling '{spelling}' claimed by both {other} \
                         and {}",
                        p.name()
                    );
                }
            }
        }
        for &(alias, key) in &[
            ("bload", "bload"),
            ("block_pad", "bload"),
            ("BLOCK", "bload"),
            ("0_padding", "naive"),
            ("chunking", "sampling"),
            ("mix", "mix_pad"),
            ("first_fit_decreasing", "ffd"),
            ("bucketing", "bucket"),
        ] {
            assert_eq!(lookup(alias).unwrap().name(), key, "{alias}");
        }
        assert!(lookup("nope").is_none());
        let err = by_name("nope").unwrap_err().to_string();
        assert!(err.contains("bload"), "{err}");
    }

    #[test]
    fn every_strategy_packs_and_validates_at_native_length() {
        let cfg = ExperimentConfig::default_config();
        let ds = generate(&cfg.dataset.scaled(0.01), 5);
        for &p in registry() {
            let packed =
                super::super::pack(p, &ds.train, &cfg.packing, 5)
                    .unwrap_or_else(|e| panic!("{}: {e}", p.name()));
            validate(&packed, &ds.train, p.within_video_padding())
                .unwrap_or_else(|e| panic!("{}: {e}", p.name()));
            assert_eq!(packed.stats.strategy, p.label());
            assert_eq!(packed.block_len,
                       p.native_block_len(&cfg.packing));
        }
    }

    #[test]
    fn only_bload_has_streaming_mode_today() {
        let cfg = ExperimentConfig::default_config().packing;
        let ctx = PackContext::new(&cfg, cfg.t_max, 0);
        for &p in registry() {
            let has = p.streaming(&ctx).is_some();
            assert_eq!(has, p.name() == "bload", "{}", p.name());
        }
    }

    #[test]
    fn streaming_context_defaults_cover_block_len() {
        let ctx = PackContext::streaming(94, 32, 2, 7);
        assert_eq!(ctx.block_len, 94);
        assert_eq!(ctx.window, 32);
        assert_eq!(ctx.max_latency, 2);
        assert_eq!(ctx.t_block, 94);
    }
}
