//! Packed-dataset invariant validator.
//!
//! Run after every pack (cheap) and hammered by randomized property tests:
//! whatever strategy produced the blocks, the result must be structurally
//! sound before it reaches the loader.

use std::collections::HashMap;

use crate::dataset::Split;
use crate::error::{Error, Result};

use super::{Block, PackedDataset};

/// Strategy-independent invariants.
///
/// 1. every block's placements are in-bounds, ordered, non-overlapping;
/// 2. no source frame is placed twice (spans of one video never overlap);
/// 3. placements reference only videos of the split;
/// 4. spans only cover `[0, len)` of their video **unless**
///    `allow_within_video_padding` (mix pad's trailing lane padding);
/// 5. stats are consistent with the blocks.
pub fn validate(packed: &PackedDataset, split: &Split,
                allow_within_video_padding: bool) -> Result<()> {
    let lens: HashMap<u32, usize> = split
        .videos
        .iter()
        .map(|v| (v.id, v.len as usize))
        .collect();

    // Per-video coverage intervals for overlap detection.
    let mut covered: HashMap<u32, Vec<(usize, usize)>> = HashMap::new();
    let mut total_slots = 0usize;
    let mut placed_real = 0usize;

    for (bi, b) in packed.blocks.iter().enumerate() {
        total_slots += b.len;
        let mut cursor = 0usize;
        for (si, s) in b.segments.iter().enumerate() {
            if s.at < cursor {
                return Err(Error::Packing(format!(
                    "block {bi} segment {si} at {} overlaps previous \
                     (cursor {cursor})",
                    s.at
                )));
            }
            if s.at + s.len > b.len {
                return Err(Error::Packing(format!(
                    "block {bi} segment {si} [{}, {}) exceeds block len {}",
                    s.at,
                    s.at + s.len,
                    b.len
                )));
            }
            if s.len == 0 {
                return Err(Error::Packing(format!(
                    "block {bi} segment {si} has zero length"
                )));
            }
            cursor = s.at + s.len;
            let vlen = *lens.get(&s.video).ok_or_else(|| {
                Error::Packing(format!(
                    "block {bi} references unknown video {}",
                    s.video
                ))
            })?;
            let real_end = s.src_start + s.len;
            if real_end > vlen && !allow_within_video_padding {
                return Err(Error::Packing(format!(
                    "block {bi} segment {si} covers [{}, {real_end}) of \
                     video {} (len {vlen})",
                    s.src_start, s.video
                )));
            }
            let real = s.len.min(vlen.saturating_sub(s.src_start));
            placed_real += real;
            if real > 0 {
                covered
                    .entry(s.video)
                    .or_default()
                    .push((s.src_start, s.src_start + real));
            }
        }
    }

    // No frame placed twice.
    for (video, spans) in covered.iter_mut() {
        spans.sort_unstable();
        for w in spans.windows(2) {
            if w[0].1 > w[1].0 {
                return Err(Error::Packing(format!(
                    "video {video}: frame ranges {:?} and {:?} overlap",
                    w[0], w[1]
                )));
            }
        }
    }

    // Stats cross-check.
    let s = &packed.stats;
    if s.blocks != packed.blocks.len()
        || s.total_slots != total_slots
        || s.frames_kept != placed_real
        || s.padding != total_slots - placed_real
        || s.frames_deleted != split.total_frames().saturating_sub(placed_real)
    {
        return Err(Error::Packing(format!(
            "stats inconsistent with blocks: {s:?} (recount: slots \
             {total_slots}, kept {placed_real})"
        )));
    }
    Ok(())
}

/// Summary returned by a completed [`StreamValidator`] pass.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct StreamSummary {
    pub blocks: usize,
    pub total_slots: usize,
    /// Slots not covered by any placement.
    pub padding: usize,
    /// Real source frames placed.
    pub frames_placed: usize,
    /// Videos placed (each exactly once, whole and contiguous).
    pub videos_placed: usize,
    /// Videos of the split never seen in the stream (only allowed by
    /// [`StreamValidator::finish_partial`], e.g. blocks dropped for rank
    /// equality).
    pub videos_unplaced: usize,
    /// Frames of the never-placed videos.
    pub frames_unplaced: usize,
}

/// Incremental invariant checker for a *stream* of blocks.
///
/// The offline [`validate`] needs the whole [`PackedDataset`]; streaming
/// packers (the `ingest` service, [`super::online::OnlinePacker`]) emit
/// blocks one at a time and never hold them all. `StreamValidator` checks
/// the same whole-video invariants block-by-block in O(segments) per
/// block:
///
/// 1. every block has the agreed uniform length and at least one segment;
/// 2. placements are ordered, non-overlapping and in-bounds;
/// 3. every placement is a *whole* video (`src_start == 0`,
///    `len == video len`) of the split — the contiguous-placement
///    guarantee BLoad shares with its online variant;
/// 4. no video is placed twice anywhere in the stream;
/// 5. at [`finish`](StreamValidator::finish): every video was placed
///    (no frame deleted).
#[derive(Debug)]
pub struct StreamValidator {
    lens: HashMap<u32, usize>,
    placed: std::collections::HashSet<u32>,
    block_len: usize,
    summary: StreamSummary,
}

impl StreamValidator {
    pub fn new(split: &Split, block_len: usize) -> StreamValidator {
        StreamValidator {
            lens: split
                .videos
                .iter()
                .map(|v| (v.id, v.len as usize))
                .collect(),
            placed: Default::default(),
            block_len,
            summary: StreamSummary::default(),
        }
    }

    /// Check one block as it comes off the stream.
    pub fn check_block(&mut self, b: &Block) -> Result<()> {
        let bi = self.summary.blocks;
        if b.len != self.block_len {
            return Err(Error::Packing(format!(
                "stream block {bi} has len {} != agreed {}",
                b.len, self.block_len
            )));
        }
        if b.segments.is_empty() {
            return Err(Error::Packing(format!(
                "stream block {bi} is empty (all padding)"
            )));
        }
        let mut cursor = 0usize;
        for (si, s) in b.segments.iter().enumerate() {
            if s.at < cursor {
                return Err(Error::Packing(format!(
                    "stream block {bi} segment {si} at {} overlaps \
                     previous (cursor {cursor})",
                    s.at
                )));
            }
            if s.at + s.len > b.len {
                return Err(Error::Packing(format!(
                    "stream block {bi} segment {si} [{}, {}) exceeds block \
                     len {}",
                    s.at,
                    s.at + s.len,
                    b.len
                )));
            }
            let vlen = *self.lens.get(&s.video).ok_or_else(|| {
                Error::Packing(format!(
                    "stream block {bi} references unknown video {}",
                    s.video
                ))
            })?;
            if s.src_start != 0 || s.len != vlen {
                return Err(Error::Packing(format!(
                    "stream block {bi} segment {si} covers [{}, {}) of \
                     video {} (len {vlen}); streaming placements must be \
                     whole contiguous videos",
                    s.src_start,
                    s.src_start + s.len,
                    s.video
                )));
            }
            if !self.placed.insert(s.video) {
                return Err(Error::Packing(format!(
                    "stream block {bi} places video {} a second time",
                    s.video
                )));
            }
            cursor = s.at + s.len;
            self.summary.frames_placed += s.len;
        }
        self.summary.blocks += 1;
        self.summary.total_slots += b.len;
        self.summary.padding += b.padding();
        Ok(())
    }

    /// Strict end-of-stream check: every video of the split must have been
    /// placed (the paper's no-frame-deleted guarantee).
    pub fn finish(self) -> Result<StreamSummary> {
        let summary = self.finish_partial()?;
        if summary.videos_unplaced > 0 {
            return Err(Error::Packing(format!(
                "stream ended with {} video(s) / {} frame(s) never placed",
                summary.videos_unplaced, summary.frames_unplaced
            )));
        }
        Ok(summary)
    }

    /// End-of-stream check tolerating *whole* missing videos (e.g. blocks
    /// dropped by the ingest service to equalize per-rank step counts).
    /// Partially-covered or double-placed videos are still errors.
    pub fn finish_partial(mut self) -> Result<StreamSummary> {
        for (id, len) in &self.lens {
            if self.placed.contains(id) {
                self.summary.videos_placed += 1;
            } else {
                self.summary.videos_unplaced += 1;
                self.summary.frames_unplaced += *len;
            }
        }
        Ok(self.summary)
    }
}

/// One-shot strict streaming validation over an iterator of blocks.
pub fn validate_stream<'a, I>(blocks: I, split: &Split, block_len: usize)
                              -> Result<StreamSummary>
where
    I: IntoIterator<Item = &'a Block>,
{
    let mut v = StreamValidator::new(split, block_len);
    for b in blocks {
        v.check_block(b)?;
    }
    v.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExperimentConfig, PackingConfig};
    use crate::dataset::synthetic::generate;
    use crate::packing::{by_name, pack, registry, Block, PackedDataset,
                         Packer, Placement};

    fn small_split() -> crate::dataset::Split {
        let cfg = ExperimentConfig::default_config().dataset.scaled(0.01);
        generate(&cfg, 3).train
    }

    fn pack_cfg() -> PackingConfig {
        ExperimentConfig::default_config().packing
    }

    #[test]
    fn all_strategies_validate_over_random_seeds() {
        let split = small_split();
        let cfg = pack_cfg();
        for seed in 0..25 {
            for &strat in registry() {
                let packed = pack(strat, &split, &cfg, seed).unwrap();
                let allow = strat.within_video_padding();
                validate(&packed, &split, allow).unwrap_or_else(|e| {
                    panic!("{} seed {seed}: {e}", strat.name())
                });
            }
        }
    }

    #[test]
    fn detects_overlapping_segments() {
        let split = small_split();
        let v = split.videos[0];
        let mut b = Block::new(20);
        b.segments.push(Placement { at: 0, video: v.id, src_start: 0, len: 3 });
        b.segments.push(Placement { at: 2, video: split.videos[1].id,
                                    src_start: 0, len: 3 });
        let packed = PackedDataset::finalize("x", 20, vec![b], &split);
        assert!(validate(&packed, &split, false).is_err());
    }

    #[test]
    fn detects_double_placed_frames() {
        let split = small_split();
        let v = split.videos.iter().find(|v| v.len >= 4).unwrap();
        let mut b = Block::new(40);
        b.push(v.id, 0, 3).unwrap();
        b.push(v.id, 1, 3).unwrap(); // frames 1..3 placed twice
        let packed = PackedDataset::finalize("x", 40, vec![b], &split);
        let err = validate(&packed, &split, false).unwrap_err().to_string();
        assert!(err.contains("overlap"), "{err}");
    }

    #[test]
    fn detects_unknown_video() {
        let split = small_split();
        let mut b = Block::new(10);
        b.push(0xDEAD_BEEF, 0, 3).unwrap();
        let packed = PackedDataset::finalize("x", 10, vec![b], &split);
        assert!(validate(&packed, &split, false).is_err());
    }

    #[test]
    fn detects_span_past_video_end() {
        let split = small_split();
        let v = split.videos[0];
        let mut b = Block::new(200);
        b.push(v.id, 0, v.len as usize + 2).unwrap();
        let packed = PackedDataset::finalize("x", 200, vec![b], &split);
        assert!(validate(&packed, &split, false).is_err());
        // ...but mix pad's within-video padding is allowed when flagged.
        assert!(validate(&packed, &split, true).is_ok());
    }

    #[test]
    fn detects_corrupted_stats() {
        let split = small_split();
        let cfg = pack_cfg();
        let mut packed =
            pack(by_name("bload").unwrap(), &split, &cfg, 0).unwrap();
        packed.stats.padding += 1;
        assert!(validate(&packed, &split, false).is_err());
    }

    #[test]
    fn stream_accepts_offline_bload_blocks() {
        let split = small_split();
        let packed =
            pack(by_name("bload").unwrap(), &split, &pack_cfg(), 3)
                .unwrap();
        let summary =
            validate_stream(packed.blocks.iter(), &split, packed.block_len)
                .unwrap();
        assert_eq!(summary.blocks, packed.blocks.len());
        assert_eq!(summary.padding, packed.stats.padding);
        assert_eq!(summary.frames_placed, split.total_frames());
        assert_eq!(summary.videos_unplaced, 0);
    }

    #[test]
    fn stream_detects_double_placement_across_blocks() {
        let split = small_split();
        let v = split.videos[0];
        let mk = |id: u32, len: usize| {
            let mut b = Block::new(94);
            b.push(id, 0, len).unwrap();
            b
        };
        let a = mk(v.id, v.len as usize);
        let b = mk(v.id, v.len as usize);
        let err = validate_stream([&a, &b], &split, 94).unwrap_err();
        assert!(err.to_string().contains("second time"), "{err}");
    }

    #[test]
    fn stream_detects_partial_video_and_bad_len() {
        let split = small_split();
        let v = split.videos.iter().find(|v| v.len >= 3).unwrap();
        let mut b = Block::new(94);
        b.push(v.id, 0, v.len as usize - 1).unwrap();
        let err = validate_stream([&b], &split, 94).unwrap_err();
        assert!(err.to_string().contains("whole contiguous"), "{err}");
        // Wrong uniform length.
        let mut b = Block::new(40);
        b.push(v.id, 0, v.len as usize).unwrap();
        assert!(validate_stream([&b], &split, 94).is_err());
        // Empty block.
        let b = Block::new(94);
        assert!(validate_stream([&b], &split, 94).is_err());
    }

    #[test]
    fn stream_strict_vs_partial_finish() {
        let split = small_split();
        let v = split.videos[0];
        let mut b = Block::new(94);
        b.push(v.id, 0, v.len as usize).unwrap();
        let mut sv = StreamValidator::new(&split, 94);
        sv.check_block(&b).unwrap();
        let err = sv.finish().unwrap_err();
        assert!(err.to_string().contains("never placed"), "{err}");
        let mut sv = StreamValidator::new(&split, 94);
        sv.check_block(&b).unwrap();
        let summary = sv.finish_partial().unwrap();
        assert_eq!(summary.videos_placed, 1);
        assert_eq!(summary.videos_unplaced, split.videos.len() - 1);
    }
}
