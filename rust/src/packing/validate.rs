//! Packed-dataset invariant validator.
//!
//! Run after every pack (cheap) and hammered by randomized property tests:
//! whatever strategy produced the blocks, the result must be structurally
//! sound before it reaches the loader.

use std::collections::HashMap;

use crate::dataset::Split;
use crate::error::{Error, Result};

use super::PackedDataset;

/// Strategy-independent invariants.
///
/// 1. every block's placements are in-bounds, ordered, non-overlapping;
/// 2. no source frame is placed twice (spans of one video never overlap);
/// 3. placements reference only videos of the split;
/// 4. spans only cover `[0, len)` of their video **unless**
///    `allow_within_video_padding` (mix pad's trailing lane padding);
/// 5. stats are consistent with the blocks.
pub fn validate(packed: &PackedDataset, split: &Split,
                allow_within_video_padding: bool) -> Result<()> {
    let lens: HashMap<u32, usize> = split
        .videos
        .iter()
        .map(|v| (v.id, v.len as usize))
        .collect();

    // Per-video coverage intervals for overlap detection.
    let mut covered: HashMap<u32, Vec<(usize, usize)>> = HashMap::new();
    let mut total_slots = 0usize;
    let mut placed_real = 0usize;

    for (bi, b) in packed.blocks.iter().enumerate() {
        total_slots += b.len;
        let mut cursor = 0usize;
        for (si, s) in b.segments.iter().enumerate() {
            if s.at < cursor {
                return Err(Error::Packing(format!(
                    "block {bi} segment {si} at {} overlaps previous \
                     (cursor {cursor})",
                    s.at
                )));
            }
            if s.at + s.len > b.len {
                return Err(Error::Packing(format!(
                    "block {bi} segment {si} [{}, {}) exceeds block len {}",
                    s.at,
                    s.at + s.len,
                    b.len
                )));
            }
            if s.len == 0 {
                return Err(Error::Packing(format!(
                    "block {bi} segment {si} has zero length"
                )));
            }
            cursor = s.at + s.len;
            let vlen = *lens.get(&s.video).ok_or_else(|| {
                Error::Packing(format!(
                    "block {bi} references unknown video {}",
                    s.video
                ))
            })?;
            let real_end = s.src_start + s.len;
            if real_end > vlen && !allow_within_video_padding {
                return Err(Error::Packing(format!(
                    "block {bi} segment {si} covers [{}, {real_end}) of \
                     video {} (len {vlen})",
                    s.src_start, s.video
                )));
            }
            let real = s.len.min(vlen.saturating_sub(s.src_start));
            placed_real += real;
            if real > 0 {
                covered
                    .entry(s.video)
                    .or_default()
                    .push((s.src_start, s.src_start + real));
            }
        }
    }

    // No frame placed twice.
    for (video, spans) in covered.iter_mut() {
        spans.sort_unstable();
        for w in spans.windows(2) {
            if w[0].1 > w[1].0 {
                return Err(Error::Packing(format!(
                    "video {video}: frame ranges {:?} and {:?} overlap",
                    w[0], w[1]
                )));
            }
        }
    }

    // Stats cross-check.
    let s = &packed.stats;
    if s.blocks != packed.blocks.len()
        || s.total_slots != total_slots
        || s.frames_kept != placed_real
        || s.padding != total_slots - placed_real
        || s.frames_deleted != split.total_frames().saturating_sub(placed_real)
    {
        return Err(Error::Packing(format!(
            "stats inconsistent with blocks: {s:?} (recount: slots \
             {total_slots}, kept {placed_real})"
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExperimentConfig, PackingConfig, StrategyName};
    use crate::dataset::synthetic::generate;
    use crate::packing::{pack, Block, PackedDataset, Placement};

    fn small_split() -> crate::dataset::Split {
        let cfg = ExperimentConfig::default_config().dataset.scaled(0.01);
        generate(&cfg, 3).train
    }

    fn pack_cfg() -> PackingConfig {
        ExperimentConfig::default_config().packing
    }

    #[test]
    fn all_strategies_validate_over_random_seeds() {
        let split = small_split();
        let cfg = pack_cfg();
        for seed in 0..25 {
            for strat in StrategyName::all() {
                let packed = pack(strat, &split, &cfg, seed).unwrap();
                let allow = strat == StrategyName::MixPad;
                validate(&packed, &split, allow).unwrap_or_else(|e| {
                    panic!("{strat} seed {seed}: {e}")
                });
            }
        }
    }

    #[test]
    fn detects_overlapping_segments() {
        let split = small_split();
        let v = split.videos[0];
        let mut b = Block::new(20);
        b.segments.push(Placement { at: 0, video: v.id, src_start: 0, len: 3 });
        b.segments.push(Placement { at: 2, video: split.videos[1].id,
                                    src_start: 0, len: 3 });
        let packed = PackedDataset::finalize("x", 20, vec![b], &split);
        assert!(validate(&packed, &split, false).is_err());
    }

    #[test]
    fn detects_double_placed_frames() {
        let split = small_split();
        let v = split.videos.iter().find(|v| v.len >= 4).unwrap();
        let mut b = Block::new(40);
        b.push(v.id, 0, 3).unwrap();
        b.push(v.id, 1, 3).unwrap(); // frames 1..3 placed twice
        let packed = PackedDataset::finalize("x", 40, vec![b], &split);
        let err = validate(&packed, &split, false).unwrap_err().to_string();
        assert!(err.contains("overlap"), "{err}");
    }

    #[test]
    fn detects_unknown_video() {
        let split = small_split();
        let mut b = Block::new(10);
        b.push(0xDEAD_BEEF, 0, 3).unwrap();
        let packed = PackedDataset::finalize("x", 10, vec![b], &split);
        assert!(validate(&packed, &split, false).is_err());
    }

    #[test]
    fn detects_span_past_video_end() {
        let split = small_split();
        let v = split.videos[0];
        let mut b = Block::new(200);
        b.push(v.id, 0, v.len as usize + 2).unwrap();
        let packed = PackedDataset::finalize("x", 200, vec![b], &split);
        assert!(validate(&packed, &split, false).is_err());
        // ...but mix pad's within-video padding is allowed when flagged.
        assert!(validate(&packed, &split, true).is_ok());
    }

    #[test]
    fn detects_corrupted_stats() {
        let split = small_split();
        let cfg = pack_cfg();
        let mut packed =
            pack(StrategyName::BLoad, &split, &cfg, 0).unwrap();
        packed.stats.padding += 1;
        assert!(validate(&packed, &split, false).is_err());
    }
}
