//! ASCII visualization of packed blocks — regenerates the paper's Figs
//! 1/3/4/5 as terminal art (`bload pack-viz`).
//!
//! ```text
//! block  0 │ A A A A A A │ B B B B ░ ░ │            (block_pad)
//! ```

use std::collections::HashMap;

use crate::dataset::Split;

use super::PackedDataset;

/// Glyphs used for video identities (cycled).
const GLYPHS: &[u8] = b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz";

/// Render the raw (unpacked) dataset, one row per video — Fig 1.
pub fn render_dataset(split: &Split, max_rows: usize) -> String {
    let mut out = String::new();
    for (i, v) in split.videos.iter().take(max_rows).enumerate() {
        let g = GLYPHS[i % GLYPHS.len()] as char;
        out.push_str(&format!("V{:<3} │ ", v.id));
        for _ in 0..v.len {
            out.push(g);
            out.push(' ');
        }
        out.push('\n');
    }
    if split.videos.len() > max_rows {
        out.push_str(&format!("… ({} more videos)\n",
                              split.videos.len() - max_rows));
    }
    out
}

/// Render packed blocks, one row per block — Figs 3/4/5. `░` = padding.
/// Within-video padding lanes (mix pad) render as the video's lowercase
/// glyph.
pub fn render_packed(packed: &PackedDataset, split: &Split, max_rows: usize)
                     -> String {
    let lens: HashMap<u32, usize> = split
        .videos
        .iter()
        .map(|v| (v.id, v.len as usize))
        .collect();
    // Stable glyph per video id, in first-appearance order.
    let mut glyph: HashMap<u32, char> = HashMap::new();
    let mut next = 0usize;
    let mut out = String::new();
    for (bi, b) in packed.blocks.iter().take(max_rows).enumerate() {
        out.push_str(&format!("block {bi:>3} │ "));
        let mut row = vec!['░'; b.len];
        for s in &b.segments {
            let g = *glyph.entry(s.video).or_insert_with(|| {
                let c = GLYPHS[next % GLYPHS.len()] as char;
                next += 1;
                c
            });
            let vlen = lens.get(&s.video).copied().unwrap_or(usize::MAX);
            for k in 0..s.len {
                let real = s.src_start + k < vlen;
                row[s.at + k] = if real {
                    g
                } else {
                    g.to_ascii_lowercase()
                };
            }
        }
        for c in row {
            out.push(c);
            out.push(' ');
        }
        out.push_str(&format!("│ reset={:?}\n", b.reset_table()));
    }
    if packed.blocks.len() > max_rows {
        out.push_str(&format!("… ({} more blocks)\n",
                              packed.blocks.len() - max_rows));
    }
    out.push_str(&format!("{}\n", packed.stats));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::dataset::synthetic::{generate, tiny_config};
    use crate::packing::{by_name, pack};

    #[test]
    fn renders_toy_dataset_and_blocks() {
        let ds = generate(&tiny_config(), 1);
        let fig1 = render_dataset(&ds.train, 10);
        assert_eq!(fig1.lines().count(), 8);
        let cfg = {
            let mut c = ExperimentConfig::default_config().packing;
            c.t_max = 6;
            c
        };
        let packed =
            pack(by_name("bload").unwrap(), &ds.train, &cfg, 0).unwrap();
        let fig5 = render_packed(&packed, &ds.train, 50);
        assert!(fig5.contains("block   0"), "{fig5}");
        assert!(fig5.contains("reset="), "{fig5}");
        assert!(fig5.contains("block_pad"));
    }

    #[test]
    fn padding_glyph_appears_for_naive() {
        let ds = generate(&tiny_config(), 2);
        let cfg = {
            let mut c = ExperimentConfig::default_config().packing;
            c.t_max = 6;
            c
        };
        let packed =
            pack(by_name("naive").unwrap(), &ds.train, &cfg, 0).unwrap();
        let art = render_packed(&packed, &ds.train, 50);
        assert!(art.contains('░'), "naive padding must be visible\n{art}");
    }

    #[test]
    fn row_truncation() {
        let ds = generate(&tiny_config(), 3);
        let s = render_dataset(&ds.train, 2);
        assert!(s.contains("more videos"));
    }
}
