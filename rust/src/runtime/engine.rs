//! The PJRT execution engine: compile-once, shape-checked execution of the
//! three model artifacts.

use std::path::Path;

use crate::error::{Error, Result};
use crate::loader::DeviceBatch;
use crate::log_info;

use super::manifest::ProfileSpec;

/// Output of one `grad_step` call.
#[derive(Debug, Clone)]
pub struct GradOut {
    pub loss: f32,
    pub grads: Vec<f32>,
    pub state_out: Vec<f32>,
}

/// Output of one `infer_step` call.
#[derive(Debug, Clone)]
pub struct InferOut {
    /// `[B, T, O, C]` row-major.
    pub logits: Vec<f32>,
    pub state_out: Vec<f32>,
}

/// Compiled executables for one profile on the PJRT CPU client.
pub struct Engine {
    pub spec: ProfileSpec,
    client: xla::PjRtClient,
    grad_exe: xla::PjRtLoadedExecutable,
    infer_exe: xla::PjRtLoadedExecutable,
    update_exe: xla::PjRtLoadedExecutable,
    /// Executions performed (telemetry).
    pub executions: std::cell::Cell<u64>,
}

fn compile(client: &xla::PjRtClient, path: &Path)
           -> Result<xla::PjRtLoadedExecutable> {
    let text_path = path.to_str().ok_or_else(|| {
        Error::Runtime(format!("non-utf8 artifact path {path:?}"))
    })?;
    let proto = xla::HloModuleProto::from_text_file(text_path)
        .map_err(|e| Error::Runtime(format!(
            "load HLO text {text_path}: {e}"
        )))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    Ok(client.compile(&comp)?)
}

fn literal(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let want: usize = dims.iter().product();
    debug_assert_eq!(want, data.len());
    // Single-copy construction straight into the shaped literal —
    // `vec1(..).reshape(..)` would copy twice (§Perf L3 optimization #1).
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8,
                                   std::mem::size_of_val(data))
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        dims,
        bytes,
    )?)
}

fn scalar(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

impl Engine {
    /// Compile the three artifacts of `spec` on a fresh CPU client.
    pub fn load(spec: ProfileSpec) -> Result<Engine> {
        let t0 = std::time::Instant::now();
        let client = xla::PjRtClient::cpu()?;
        let grad_exe = compile(&client, &spec.grad_step)?;
        let infer_exe = compile(&client, &spec.infer_step)?;
        let update_exe = compile(&client, &spec.apply_update)?;
        log_info!(
            "engine '{}' compiled in {:.2}s (P={}, B={}, T={})",
            spec.name,
            t0.elapsed().as_secs_f64(),
            spec.param_count,
            spec.batch,
            spec.block_len
        );
        Ok(Engine {
            spec,
            client,
            grad_exe,
            infer_exe,
            update_exe,
            executions: std::cell::Cell::new(0),
        })
    }

    /// Platform string of the underlying PJRT client.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn check_batch(&self, b: &DeviceBatch, artifact: &str) -> Result<()> {
        let s = &self.spec;
        let checks = [
            (0usize, "batch", vec![b.batch], vec![s.batch]),
            (1, "block_len", vec![b.block_len], vec![s.block_len]),
            (2, "objects", vec![b.objects], vec![s.objects]),
            (3, "feat_dim", vec![b.feat_dim], vec![s.feat_dim]),
            (4, "classes", vec![b.classes], vec![s.classes]),
        ];
        for (index, name, got, expected) in checks {
            if got != expected {
                return Err(Error::Shape {
                    artifact: artifact.into(),
                    index,
                    name: name.into(),
                    expected,
                    got,
                });
            }
        }
        Ok(())
    }

    fn run(&self, exe: &xla::PjRtLoadedExecutable, args: &[&xla::Literal])
           -> Result<Vec<xla::Literal>> {
        self.executions.set(self.executions.get() + 1);
        let result = exe.execute::<&xla::Literal>(args)?[0][0]
            .to_literal_sync()?;
        Ok(result.to_tuple()?)
    }

    /// Upload the flat parameter vector once; the returned literal can be
    /// reused across every rank's `grad_step`/`infer_step` of a DDP step
    /// (parameters are identical on all ranks — §Perf L3 optimization #2).
    pub fn params_literal(&self, params: &[f32]) -> Result<xla::Literal> {
        if params.len() != self.spec.param_count {
            return Err(Error::Runtime(format!(
                "params len {} != {}",
                params.len(),
                self.spec.param_count
            )));
        }
        literal(params, &[self.spec.param_count])
    }

    /// Execute `grad_step`:
    /// `(params, feats, labels, frame_mask, seg_ids, state_in)` →
    /// `(loss, grads, state_out)`.
    pub fn grad_step(&self, params: &[f32], batch: &DeviceBatch,
                     state_in: &[f32]) -> Result<GradOut> {
        let plit = self.params_literal(params)?;
        self.grad_step_lit(&plit, batch, state_in)
    }

    /// `grad_step` with a pre-uploaded parameter literal.
    pub fn grad_step_lit(&self, params: &xla::Literal, batch: &DeviceBatch,
                         state_in: &[f32]) -> Result<GradOut> {
        self.check_batch(batch, "grad_step")?;
        let s = &self.spec;
        if state_in.len() != s.batch * s.state_dim {
            return Err(Error::Runtime(format!(
                "grad_step: state len {} != {}",
                state_in.len(),
                s.batch * s.state_dim
            )));
        }
        let (b, t, o) = (s.batch, s.block_len, s.objects);
        let feats = literal(&batch.feats, &[b, t, o, s.feat_dim])?;
        let labels = literal(&batch.labels, &[b, t, o, s.classes])?;
        let mask = literal(&batch.frame_mask, &[b, t])?;
        let seg = literal(&batch.seg_ids, &[b, t])?;
        let state = literal(state_in, &[b, s.state_dim])?;
        let args = [params, &feats, &labels, &mask, &seg, &state];
        let out = self.run(&self.grad_exe, &args)?;
        if out.len() != 3 {
            return Err(Error::Runtime(format!(
                "grad_step returned {} outputs, want 3",
                out.len()
            )));
        }
        Ok(GradOut {
            loss: out[0].to_vec::<f32>()?[0],
            grads: out[1].to_vec::<f32>()?,
            state_out: out[2].to_vec::<f32>()?,
        })
    }

    /// Execute `infer_step`:
    /// `(params, feats, frame_mask, seg_ids, state_in)` →
    /// `(logits, state_out)`.
    pub fn infer_step(&self, params: &[f32], batch: &DeviceBatch,
                      state_in: &[f32]) -> Result<InferOut> {
        let plit = self.params_literal(params)?;
        self.infer_step_lit(&plit, batch, state_in)
    }

    /// `infer_step` with a pre-uploaded parameter literal.
    pub fn infer_step_lit(&self, params: &xla::Literal, batch: &DeviceBatch,
                          state_in: &[f32]) -> Result<InferOut> {
        self.check_batch(batch, "infer_step")?;
        let s = &self.spec;
        let (b, t, o) = (s.batch, s.block_len, s.objects);
        let feats = literal(&batch.feats, &[b, t, o, s.feat_dim])?;
        let mask = literal(&batch.frame_mask, &[b, t])?;
        let seg = literal(&batch.seg_ids, &[b, t])?;
        let state = literal(state_in, &[b, s.state_dim])?;
        let args = [params, &feats, &mask, &seg, &state];
        let out = self.run(&self.infer_exe, &args)?;
        if out.len() != 2 {
            return Err(Error::Runtime(format!(
                "infer_step returned {} outputs, want 2",
                out.len()
            )));
        }
        Ok(InferOut {
            logits: out[0].to_vec::<f32>()?,
            state_out: out[1].to_vec::<f32>()?,
        })
    }

    /// Execute `apply_update` (SGD + momentum):
    /// `(params, mom, grads, lr, momentum)` → `(params', mom')`.
    /// Updates `params` and `mom` in place.
    pub fn apply_update(&self, params: &mut Vec<f32>, mom: &mut Vec<f32>,
                        grads: &[f32], lr: f32, momentum: f32) -> Result<()> {
        let p = self.spec.param_count;
        if params.len() != p || mom.len() != p || grads.len() != p {
            return Err(Error::Runtime(format!(
                "apply_update: buffer lens ({}, {}, {}) != {p}",
                params.len(),
                mom.len(),
                grads.len()
            )));
        }
        let pl = literal(params, &[p])?;
        let ml = literal(mom, &[p])?;
        let gl = literal(grads, &[p])?;
        let lrl = scalar(lr);
        let mml = scalar(momentum);
        let args = [&pl, &ml, &gl, &lrl, &mml];
        let out = self.run(&self.update_exe, &args)?;
        *params = out[0].to_vec::<f32>()?;
        *mom = out[1].to_vec::<f32>()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::ArtifactManifest;
    use std::path::PathBuf;

    fn engine() -> Option<Engine> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let m = ArtifactManifest::load(&dir).unwrap();
        let spec = m.profile("tiny").unwrap().clone();
        Some(Engine::load(spec).unwrap())
    }

    fn fake_batch(spec: &ProfileSpec, fill: f32) -> DeviceBatch {
        let (b, t, o, f, c) = (spec.batch, spec.block_len, spec.objects,
                               spec.feat_dim, spec.classes);
        DeviceBatch {
            feats: vec![fill; b * t * o * f],
            labels: vec![1.0; b * t * o * c],
            frame_mask: vec![1.0; b * t],
            seg_ids: vec![0.0; b * t],
            block_ids: vec![0, 1],
            batch: b,
            block_len: t,
            objects: o,
            feat_dim: f,
            classes: c,
            real_frames: b * t,
            slots: b * t,
            pool: None,
        }
    }

    #[test]
    fn grad_step_runs_and_sgd_reduces_loss() {
        let Some(eng) = engine() else { return };
        let mut params = eng.spec.load_init_params().unwrap();
        let mut mom = vec![0.0; params.len()];
        let batch = fake_batch(&eng.spec, 0.3);
        let state = vec![0.0; eng.spec.batch * eng.spec.state_dim];
        let first = eng.grad_step(&params, &batch, &state).unwrap();
        assert!(first.loss.is_finite() && first.loss > 0.0);
        assert_eq!(first.grads.len(), params.len());
        let mut last = first.loss;
        for _ in 0..10 {
            let g = eng.grad_step(&params, &batch, &state).unwrap();
            eng.apply_update(&mut params, &mut mom, &g.grads, 0.5, 0.9)
                .unwrap();
            last = g.loss;
        }
        assert!(
            last < first.loss * 0.9,
            "loss did not drop: {} -> {last}",
            first.loss
        );
    }

    #[test]
    fn infer_step_shapes() {
        let Some(eng) = engine() else { return };
        let params = eng.spec.load_init_params().unwrap();
        let batch = fake_batch(&eng.spec, 0.1);
        let state = vec![0.0; eng.spec.batch * eng.spec.state_dim];
        let out = eng.infer_step(&params, &batch, &state).unwrap();
        let s = &eng.spec;
        assert_eq!(out.logits.len(),
                   s.batch * s.block_len * s.objects * s.classes);
        assert_eq!(out.state_out.len(), s.batch * s.state_dim);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let Some(eng) = engine() else { return };
        let params = eng.spec.load_init_params().unwrap();
        let mut batch = fake_batch(&eng.spec, 0.1);
        batch.block_len += 1;
        let state = vec![0.0; eng.spec.batch * eng.spec.state_dim];
        let err = eng.grad_step(&params, &batch, &state).unwrap_err();
        assert!(matches!(err, Error::Shape { .. }), "{err}");
        let bad_state = vec![0.0; 1];
        let batch = fake_batch(&eng.spec, 0.1);
        assert!(eng.grad_step(&params, &batch, &bad_state).is_err());
    }
}
