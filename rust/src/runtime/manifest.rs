//! `artifacts/manifest.json` parsing and integrity checks.

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::jsonio::{parse, Value};

/// One named parameter tensor inside the flat parameter vector.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
}

/// Geometry + artifact paths of one compiled profile.
#[derive(Debug, Clone)]
pub struct ProfileSpec {
    pub name: String,
    pub batch: usize,
    pub block_len: usize,
    pub objects: usize,
    pub feat_dim: usize,
    pub classes: usize,
    pub state_dim: usize,
    pub param_count: usize,
    pub params: Vec<ParamEntry>,
    pub grad_step: PathBuf,
    pub infer_step: PathBuf,
    pub apply_update: PathBuf,
    pub init_params: PathBuf,
}

impl ProfileSpec {
    fn from_value(dir: &Path, name: &str, v: &Value) -> Result<ProfileSpec> {
        let get = |k: &str| -> Result<usize> {
            v.get(k).and_then(Value::as_usize).ok_or_else(|| {
                Error::Runtime(format!(
                    "manifest profile '{name}': missing/invalid '{k}'"
                ))
            })
        };
        let arts = v.get("artifacts").ok_or_else(|| {
            Error::Runtime(format!("profile '{name}': missing artifacts"))
        })?;
        let art = |k: &str| -> Result<PathBuf> {
            arts.get(k)
                .and_then(Value::as_str)
                .map(|rel| dir.join(rel))
                .ok_or_else(|| {
                    Error::Runtime(format!(
                        "profile '{name}': missing artifact '{k}'"
                    ))
                })
        };
        let mut params = Vec::new();
        if let Some(list) = v.get("params").and_then(Value::as_array) {
            for (i, p) in list.iter().enumerate() {
                let name = p
                    .get("name")
                    .and_then(Value::as_str)
                    .ok_or_else(|| {
                        Error::Runtime(format!("param {i}: missing name"))
                    })?
                    .to_string();
                let shape = p
                    .get("shape")
                    .and_then(Value::as_array)
                    .map(|a| {
                        a.iter().filter_map(Value::as_usize).collect::<Vec<_>>()
                    })
                    .unwrap_or_default();
                params.push(ParamEntry {
                    name,
                    shape,
                    offset: p
                        .get("offset")
                        .and_then(Value::as_usize)
                        .unwrap_or(0),
                    size: p.get("size").and_then(Value::as_usize).unwrap_or(0),
                });
            }
        }
        let spec = ProfileSpec {
            name: name.to_string(),
            batch: get("batch")?,
            block_len: get("block_len")?,
            objects: get("objects")?,
            feat_dim: get("feat_dim")?,
            classes: get("classes")?,
            state_dim: get("state_dim")?,
            param_count: get("param_count")?,
            params,
            grad_step: art("grad_step")?,
            infer_step: art("infer_step")?,
            apply_update: art("apply_update")?,
            init_params: art("init_params")?,
        };
        spec.validate()?;
        Ok(spec)
    }

    fn validate(&self) -> Result<()> {
        // Param layout must be contiguous and sum to param_count.
        let mut off = 0usize;
        for p in &self.params {
            if p.offset != off {
                return Err(Error::Runtime(format!(
                    "profile '{}': param '{}' offset {} != expected {off}",
                    self.name, p.name, p.offset
                )));
            }
            let prod: usize = p.shape.iter().product();
            if prod != p.size {
                return Err(Error::Runtime(format!(
                    "profile '{}': param '{}' shape {:?} != size {}",
                    self.name, p.name, p.shape, p.size
                )));
            }
            off += p.size;
        }
        if !self.params.is_empty() && off != self.param_count {
            return Err(Error::Runtime(format!(
                "profile '{}': params sum {off} != param_count {}",
                self.name, self.param_count
            )));
        }
        Ok(())
    }

    /// Load the python-initialized flat parameter vector.
    pub fn load_init_params(&self) -> Result<Vec<f32>> {
        let raw = std::fs::read(&self.init_params)
            .map_err(|e| Error::io(self.init_params.display(), e))?;
        if raw.len() != 4 * self.param_count {
            return Err(Error::Runtime(format!(
                "init_params {} has {} bytes, want {}",
                self.init_params.display(),
                raw.len(),
                4 * self.param_count
            )));
        }
        Ok(raw
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
            .collect())
    }
}

/// The parsed manifest (all profiles).
#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    pub profiles: Vec<ProfileSpec>,
}

impl ArtifactManifest {
    /// Read `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<ArtifactManifest> {
        let path = dir.join("manifest.json");
        let src = std::fs::read_to_string(&path)
            .map_err(|e| Error::io(path.display(), e))?;
        let v = parse(&src)?;
        let profiles_v = v
            .get("profiles")
            .and_then(Value::as_object)
            .ok_or_else(|| {
                Error::Runtime("manifest: missing 'profiles'".into())
            })?;
        let mut profiles = Vec::new();
        for (name, pv) in profiles_v {
            profiles.push(ProfileSpec::from_value(dir, name, pv)?);
        }
        Ok(ArtifactManifest { profiles })
    }

    pub fn profile(&self, name: &str) -> Result<&ProfileSpec> {
        self.profiles
            .iter()
            .find(|p| p.name == name)
            .ok_or_else(|| {
                Error::Runtime(format!(
                    "profile '{name}' not in manifest (have: {:?}); run \
                     `make artifacts` with the right --profiles",
                    self.profiles.iter().map(|p| &p.name).collect::<Vec<_>>()
                ))
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn loads_built_manifest() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = ArtifactManifest::load(&dir).unwrap();
        let tiny = m.profile("tiny").unwrap();
        assert_eq!(tiny.batch, 2);
        assert_eq!(tiny.block_len, 12);
        assert!(tiny.param_count > 0);
        assert!(tiny.grad_step.exists());
        let flat = tiny.load_init_params().unwrap();
        assert_eq!(flat.len(), tiny.param_count);
        assert!(flat.iter().all(|x| x.is_finite()));
        assert!(m.profile("nonexistent").is_err());
    }

    #[test]
    fn rejects_bad_layout() {
        let v = parse(
            r#"{"batch":1,"block_len":2,"objects":1,"feat_dim":1,
                "classes":1,"state_dim":1,"param_count":10,
                "params":[{"name":"w","shape":[3],"offset":1,"size":3}],
                "artifacts":{"grad_step":"g","infer_step":"i",
                              "apply_update":"a","init_params":"p"}}"#,
        )
        .unwrap();
        let err =
            ProfileSpec::from_value(Path::new("/x"), "t", &v).unwrap_err();
        assert!(err.to_string().contains("offset"), "{err}");
    }
}
