//! PJRT runtime: load AOT'd HLO-text artifacts and execute them.
//!
//! The interchange contract (see `python/compile/aot.py` and DESIGN.md §3):
//! HLO **text** (not serialized protos — the image's xla_extension 0.5.1
//! rejects jax ≥ 0.5 64-bit instruction ids), one artifact directory per
//! *profile*, described by `artifacts/manifest.json`.
//!
//! [`manifest`] parses and validates the manifest; [`engine`] owns the
//! PJRT CPU client, compiles executables once, and exposes shape-checked
//! typed entry points (`grad_step`, `infer_step`, `apply_update`).

pub mod engine;
pub mod manifest;

pub use engine::{Engine, GradOut, InferOut};
pub use manifest::{ArtifactManifest, ParamEntry, ProfileSpec};
