//! The metric-block registry: presentation layer over [`Snapshot`].
//!
//! Mirrors `packing::registry()` — every dashboard panel is a
//! [`MetricBlock`] unit struct registered as exactly one line in
//! [`registry`], resolved by string key through [`lookup`]/[`by_name`].
//! Each block owns a *format template*: a `&'static str` with
//! `{metric.name}` placeholders substituted from a snapshot (the
//! i3status-rust block/format-template shape, re-grown here without the
//! dependency). `bload top` renders every registered block per refresh;
//! adding a panel means one unit struct plus one registry line.
//!
//! Template placeholder grammar:
//!
//! - `{<counter name>}` → the counter value, as an integer.
//! - `{<gauge name>}` → the gauge value (`%.2f`, integers unpadded).
//! - `{<histogram name>.<stat>}` with `<stat>` one of `count`, `mean`,
//!   `min`, `max`, `p50`, `p95`, `p99` → the summary stat. Histogram
//!   names ending in `_s` are seconds and render as `12.345ms`/`1.23s`;
//!   other histograms (ratios like `train.step_skew`) render raw.
//! - Anything unresolvable renders as `-` (the metric simply has not
//!   been recorded yet — normal early in a run).

use crate::error::{Error, Result};
use crate::telemetry::Snapshot;

/// One dashboard panel, registered in [`registry`].
///
/// Implementations are stateless unit structs; all run state lives in
/// the [`Snapshot`] passed to [`render`](MetricBlock::render), so a
/// single `&'static` instance serves every caller.
pub trait MetricBlock: Sync {
    /// Canonical registry key (`bload top` panel name).
    fn name(&self) -> &'static str;

    /// Accepted spellings besides [`name`](MetricBlock::name)
    /// (matched case-insensitively).
    fn aliases(&self) -> &'static [&'static str] {
        &[]
    }

    /// One-line description (shown by `bload top --list`).
    fn describe(&self) -> &'static str;

    /// Format template rendered against a snapshot (grammar above).
    fn template(&self) -> &'static str;

    /// Render this block from a frozen snapshot.
    fn render(&self, snap: &Snapshot) -> String {
        render_template(self.template(), snap)
    }
}

/// Streaming-ingest panel: queue pressure and flush behaviour.
#[derive(Debug)]
pub struct Ingest;

impl MetricBlock for Ingest {
    fn name(&self) -> &'static str {
        "ingest"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["stream"]
    }

    fn describe(&self) -> &'static str {
        "ingest service: queue depth, flush causes, block throughput"
    }

    fn template(&self) -> &'static str {
        "arrivals {ingest.arrivals}  queue {ingest.queue_depth}  \
         blocks {ingest.blocks} ({ingest.blocks_per_s}/s)  \
         flush full/lat/eos {ingest.flush_pool_full}/\
         {ingest.flush_latency}/{ingest.flush_eos}  \
         dropped {ingest.dropped_blocks}"
    }
}

/// Prefetch-loader panel: worker throughput and cache behaviour.
#[derive(Debug)]
pub struct Loader;

impl MetricBlock for Loader {
    fn name(&self) -> &'static str {
        "loader"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["prefetch"]
    }

    fn describe(&self) -> &'static str {
        "prefetch workers: batches, VideoCache hit/miss, readahead and \
         buffer-pool recycling, materialize latency"
    }

    fn template(&self) -> &'static str {
        "batches {loader.batches}  workers {loader.workers_active}  \
         cache h/m {loader.cache_hits}/{loader.cache_misses}  \
         readahead h/m \
         {loader.readahead_hits}/{loader.readahead_misses}  \
         bufpool h/m {loader.bufpool_hits}/{loader.bufpool_misses}  \
         materialize p50 {loader.materialize_s.p50} \
         p95 {loader.materialize_s.p95} p99 {loader.materialize_s.p99}"
    }
}

/// Shard-store panel: disk reads, CRC scans and pool contention.
#[derive(Debug)]
pub struct Shardstore;

impl MetricBlock for Shardstore {
    fn name(&self) -> &'static str {
        "shardstore"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["store", "pool"]
    }

    fn describe(&self) -> &'static str {
        "shard pool: reads, bytes (replay + prefetch), cache hit/miss, \
         CRC scan time"
    }

    fn template(&self) -> &'static str {
        "reads {shardstore.reads} (p95 {shardstore.read_s.p95})  \
         bytes {shardstore.read_bytes} \
         (prefetch {shardstore.prefetch_bytes})  \
         cache h/m {shardstore.cache_hits}/{shardstore.cache_misses}  \
         scans {shardstore.scans} (mean {shardstore.scan_s.mean})"
    }
}

/// Training panel: step cadence, padding overhead, rank skew.
#[derive(Debug)]
pub struct Train;

impl MetricBlock for Train {
    fn name(&self) -> &'static str {
        "train"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["trainer", "ddp"]
    }

    fn describe(&self) -> &'static str {
        "trainer: per-rank step time, padding ratio, straggler skew"
    }

    fn template(&self) -> &'static str {
        "steps {train.steps}  padding {train.padding_pct}%  \
         skew p95 {train.step_skew.p95}  \
         rank0 step p50 {train.rank0.step_s.p50} \
         p95 {train.rank0.step_s.p95}  \
         allreduce p95 {train.allreduce_s.p95}"
    }
}

/// Serving panel: `bload serve` daemon traffic and client health.
#[derive(Debug)]
pub struct Serve;

impl MetricBlock for Serve {
    fn name(&self) -> &'static str {
        "serve"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["net", "server"]
    }

    fn describe(&self) -> &'static str {
        "serve daemon: connections, request latency, bytes served, \
         client CRC failures and retries"
    }

    fn template(&self) -> &'static str {
        "conns {net.connections} (active {net.connections_active})  \
         requests {net.requests}  bytes {net.bytes_served}  \
         req p50 {net.request_s.p50} p95 {net.request_s.p95}  \
         crc fail {net.crc_failures}  retries {net.retries}"
    }
}

/// Load-test panel: `bload assault` replay-client pool health.
#[derive(Debug)]
pub struct Assault;

impl MetricBlock for Assault {
    fn name(&self) -> &'static str {
        "assault"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["loadtest", "replaypool"]
    }

    fn describe(&self) -> &'static str {
        "assault runner: replay clients, request tail latency, \
         refusals, testcase verdicts"
    }

    fn template(&self) -> &'static str {
        "clients {assault.clients}  requests {assault.requests}  \
         bytes {assault.bytes}  fail/refused \
         {assault.failures}/{assault.refused}  \
         req p50 {assault.request_s.p50} p95 {assault.request_s.p95} \
         p99 {assault.request_s.p99}  \
         cases {assault.testcases} (failed {assault.testcases_failed})"
    }
}

/// Fleet panel: client-side striping across serve daemons — shard-map
/// traffic, pool pressure and failover health.
#[derive(Debug)]
pub struct Fleet;

impl MetricBlock for Fleet {
    fn name(&self) -> &'static str {
        "fleet"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["fanout", "shardmap"]
    }

    fn describe(&self) -> &'static str {
        "fleet client: striped hosts up/down, requests, failovers, \
         pool wait and request tail latency"
    }

    fn template(&self) -> &'static str {
        "hosts {fleet.hosts} (down {fleet.hosts_down})  \
         requests {fleet.requests}  bytes {fleet.bytes}  \
         failovers {fleet.failovers}  retries {fleet.retries}  \
         pool wait p95 {fleet.pool_wait_s.p95}  \
         req p50 {fleet.request_s.p50} p95 {fleet.request_s.p95}"
    }
}

/// Every registered metric block, in dashboard render order.
pub fn registry() -> &'static [&'static dyn MetricBlock] {
    static REGISTRY: [&'static dyn MetricBlock; 7] =
        [&Ingest, &Loader, &Shardstore, &Serve, &Fleet, &Train, &Assault];
    &REGISTRY
}

/// Case-insensitive lookup by key or alias.
pub fn lookup(name: &str) -> Option<&'static dyn MetricBlock> {
    let k = name.trim().to_ascii_lowercase();
    registry()
        .iter()
        .copied()
        .find(|b| b.name() == k || b.aliases().iter().any(|&a| a == k))
}

/// [`lookup`] that errors with the list of known keys.
pub fn by_name(name: &str) -> Result<&'static dyn MetricBlock> {
    lookup(name).ok_or_else(|| {
        let known: Vec<&str> = registry().iter().map(|b| b.name()).collect();
        Error::Config(format!(
            "unknown metric block '{name}' (known: {})",
            known.join("|")
        ))
    })
}

/// Substitute `{key}` placeholders in `template` from `snap` (grammar
/// in the module docs); unresolvable keys render as `-`.
pub fn render_template(template: &str, snap: &Snapshot) -> String {
    let mut out = String::with_capacity(template.len());
    let mut rest = template;
    while let Some(i) = rest.find('{') {
        out.push_str(&rest[..i]);
        let after = &rest[i + 1..];
        match after.find('}') {
            Some(j) => {
                let key = &after[..j];
                match resolve(key, snap) {
                    Some(v) => out.push_str(&v),
                    None => out.push('-'),
                }
                rest = &after[j + 1..];
            }
            None => {
                // Unmatched brace: emit literally.
                out.push_str(&rest[i..]);
                return out;
            }
        }
    }
    out.push_str(rest);
    out
}

fn resolve(key: &str, snap: &Snapshot) -> Option<String> {
    if let Some(v) = snap.counters.get(key) {
        return Some(v.to_string());
    }
    if let Some(v) = snap.gauges.get(key) {
        return Some(fmt_gauge(*v));
    }
    let (base, stat) = key.rsplit_once('.')?;
    let h = snap.histograms.get(base)?;
    let secs = base.ends_with("_s");
    Some(match stat {
        "count" => h.count.to_string(),
        "mean" => fmt_stat(h.mean_s, secs),
        "min" => fmt_stat(h.min_s, secs),
        "max" => fmt_stat(h.max_s, secs),
        "p50" => fmt_stat(h.p50_s, secs),
        "p95" => fmt_stat(h.p95_s, secs),
        "p99" => fmt_stat(h.p99_s, secs),
        _ => return None,
    })
}

fn fmt_gauge(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v:.2}")
    }
}

fn fmt_stat(v: f64, seconds: bool) -> String {
    if !seconds {
        format!("{v:.3}")
    } else if v >= 1.0 {
        format!("{v:.2}s")
    } else {
        format!("{:.3}ms", v * 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::{names, HistSummary};

    #[test]
    fn registry_keys_unique_and_lookup_resolves_aliases() {
        // Every spelling lookup() accepts — key or alias — must resolve
        // to exactly one block; a cross-entry collision would silently
        // shadow whichever block registers later.
        let mut claimed: std::collections::HashMap<String, &str> =
            Default::default();
        for b in registry() {
            let mut mine: Vec<String> = vec![b.name().to_string()];
            mine.extend(b.aliases().iter().map(|a| a.to_string()));
            mine.sort_unstable();
            mine.dedup();
            for spelling in mine {
                if let Some(other) =
                    claimed.insert(spelling.clone(), b.name())
                {
                    panic!(
                        "spelling '{spelling}' claimed by both {other} \
                         and {}",
                        b.name()
                    );
                }
            }
        }
        for (alias, key) in [
            ("STREAM", "ingest"),
            ("prefetch", "loader"),
            ("pool", "shardstore"),
            ("net", "serve"),
            ("fanout", "fleet"),
            ("shardmap", "fleet"),
            ("ddp", "train"),
            ("loadtest", "assault"),
        ] {
            assert_eq!(lookup(alias).unwrap().name(), key, "{alias}");
        }
        assert!(lookup("nope").is_none());
        let err = by_name("nope").unwrap_err().to_string();
        assert!(err.contains("ingest"), "{err}");
    }

    fn hist(v: f64) -> HistSummary {
        HistSummary {
            count: 3,
            mean_s: v,
            min_s: v,
            max_s: v,
            p50_s: v,
            p95_s: v,
            p99_s: v,
        }
    }

    /// A snapshot covering every canonical metric name the shipped
    /// templates reference — so a template typo fails here, not as a
    /// silent `-` on the dashboard.
    fn full_snapshot() -> Snapshot {
        let mut s = Snapshot::default();
        for c in [
            names::INGEST_ARRIVALS,
            names::INGEST_BLOCKS,
            names::INGEST_FLUSH_POOL_FULL,
            names::INGEST_FLUSH_LATENCY,
            names::INGEST_FLUSH_EOS,
            names::INGEST_DROPPED_BLOCKS,
            names::INGEST_DROPPED_FRAMES,
            names::LOADER_BATCHES,
            names::LOADER_CACHE_HITS,
            names::LOADER_CACHE_MISSES,
            names::LOADER_READAHEAD_HITS,
            names::LOADER_READAHEAD_MISSES,
            names::LOADER_BUFPOOL_HITS,
            names::LOADER_BUFPOOL_MISSES,
            names::SHARD_READS,
            names::SHARD_READ_BYTES,
            names::SHARD_PREFETCH_BYTES,
            names::SHARD_CACHE_HITS,
            names::SHARD_CACHE_MISSES,
            names::SHARD_SCANS,
            names::NET_CONNECTIONS,
            names::NET_REQUESTS,
            names::NET_BYTES_SERVED,
            names::NET_CRC_FAILURES,
            names::NET_RETRIES,
            names::FLEET_REQUESTS,
            names::FLEET_BYTES,
            names::FLEET_FAILOVERS,
            names::FLEET_RETRIES,
            names::TRAIN_STEPS,
            names::TRAIN_REAL_FRAMES,
            names::TRAIN_SLOTS,
            names::ASSAULT_REQUESTS,
            names::ASSAULT_FAILURES,
            names::ASSAULT_REFUSED,
            names::ASSAULT_CASES,
            names::ASSAULT_CASES_FAILED,
            names::ASSAULT_BYTES,
        ] {
            s.counters.insert(c.to_string(), 7);
        }
        for g in [
            names::INGEST_QUEUE_DEPTH,
            names::INGEST_BLOCKS_PER_S,
            names::LOADER_WORKERS_ACTIVE,
            names::NET_CONNECTIONS_ACTIVE,
            names::FLEET_HOSTS,
            names::FLEET_HOSTS_DOWN,
            names::TRAIN_PADDING_PCT,
            names::ASSAULT_CLIENTS,
        ] {
            s.gauges.insert(g.to_string(), 2.0);
        }
        for h in [
            names::LOADER_MATERIALIZE_S.to_string(),
            names::SHARD_READ_S.to_string(),
            names::SHARD_SCAN_S.to_string(),
            names::NET_REQUEST_S.to_string(),
            names::FLEET_POOL_WAIT_S.to_string(),
            names::FLEET_REQUEST_S.to_string(),
            names::TRAIN_STEP_SKEW.to_string(),
            names::TRAIN_ALLREDUCE_S.to_string(),
            names::train_rank_step(0),
            names::ASSAULT_REQUEST_S.to_string(),
            names::ASSAULT_CONNECT_S.to_string(),
        ] {
            s.histograms.insert(h, hist(0.004));
        }
        s
    }

    #[test]
    fn every_block_renders_fully_from_canonical_names() {
        let snap = full_snapshot();
        for b in registry() {
            let r = b.render(&snap);
            assert!(!r.is_empty(), "{}", b.name());
            assert!(
                !r.contains('{') && !r.contains('-'),
                "block '{}' left unresolved placeholders: {r}",
                b.name()
            );
        }
    }

    #[test]
    fn unknown_placeholders_render_dash() {
        let snap = Snapshot::default();
        assert_eq!(render_template("x {nope} y", &snap), "x - y");
        assert_eq!(render_template("unmatched {brace", &snap),
                   "unmatched {brace");
    }

    #[test]
    fn histogram_stats_format_by_unit() {
        let mut snap = Snapshot::default();
        snap.histograms.insert("a.lat_s".into(), hist(0.0042));
        snap.histograms.insert("a.ratio".into(), hist(1.25));
        assert_eq!(render_template("{a.lat_s.p95}", &snap), "4.200ms");
        assert_eq!(render_template("{a.lat_s.count}", &snap), "3");
        assert_eq!(render_template("{a.ratio.p50}", &snap), "1.250");
        // Slow path: ≥ 1s renders in seconds.
        snap.histograms.insert("b.lat_s".into(), hist(2.5));
        assert_eq!(render_template("{b.lat_s.mean}", &snap), "2.50s");
    }

    #[test]
    fn counters_and_gauges_resolve_plain() {
        let mut snap = Snapshot::default();
        snap.counters.insert("c.n".into(), 42);
        snap.gauges.insert("g.depth".into(), 3.0);
        snap.gauges.insert("g.rate".into(), 1.5);
        assert_eq!(render_template("{c.n} {g.depth} {g.rate}", &snap),
                   "42 3 1.50");
    }
}
