//! Telemetry core: a process-wide, lock-light registry of named
//! counters, gauges and latency histograms, plus a stable JSON snapshot
//! (`format: 1`, written through [`jsonio`](crate::jsonio)).
//!
//! Layer 1 (this module) is the instrumentation surface the hot paths
//! write into: `ingest::service` (queue depth, flush causes, blocks/s),
//! `loader::prefetch` (per-worker batches, `VideoCache` hit/miss,
//! batch-materialize latency), `dataset::shardstore` (per-shard reads,
//! CRC scan time, pool lock wait) and `train::trainer` (per-rank step
//! time, padding ratio, straggler skew). Layer 2 is [`blocks`]: a
//! registry of renderable metric blocks in the same open-registry idiom
//! as `packing::registry()`, driving `bload top`.
//!
//! Design rules:
//!
//! - **Lock-light hot path.** Counters and gauges are single atomics;
//!   the registry mutex is only touched when a handle is first resolved.
//!   Instrumented loops resolve their `Arc` handles once, outside the
//!   loop. Histograms take one short `Mutex` per recorded sample.
//! - **Get-or-create by name.** `counter("x")` twice returns the *same*
//!   handle; registering a name under two different metric kinds is a
//!   programming error and panics.
//! - **Stable snapshot.** [`snapshot`] freezes the whole registry into a
//!   [`Snapshot`] whose JSON form is deterministic (`BTreeMap` key
//!   order) and diffable in CI. Counters serialize as integers and are
//!   exact below 2^53 (the `jsonio` f64 ceiling).
//!
//! Metric *names* live in [`names`] so producers, blocks and tests
//! share one vocabulary. The snapshot schema is documented on
//! [`Snapshot::to_value`] and in the README "Observability" section.

pub mod blocks;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};

use crate::error::{Error, Result};
use crate::jsonio::Value;
use crate::metrics::timer::quantiles;

/// Canonical metric names. Producers and consumers (blocks, tests, CI
/// snapshot assertions) reference these constants so spellings cannot
/// drift.
pub mod names {
    /// Counter: video arrivals accepted by the ingest queue.
    pub const INGEST_ARRIVALS: &str = "ingest.arrivals";
    /// Gauge: arrivals enqueued but not yet consumed by the packer.
    pub const INGEST_QUEUE_DEPTH: &str = "ingest.queue_depth";
    /// Counter: packed blocks dispatched to rank outputs.
    pub const INGEST_BLOCKS: &str = "ingest.blocks";
    /// Gauge: blocks/s over the pack loop's lifetime.
    pub const INGEST_BLOCKS_PER_S: &str = "ingest.blocks_per_s";
    /// Counter: pool flushes forced by a full window.
    pub const INGEST_FLUSH_POOL_FULL: &str = "ingest.flush_pool_full";
    /// Counter: pool flushes forced by the latency deadline.
    pub const INGEST_FLUSH_LATENCY: &str = "ingest.flush_latency";
    /// Counter: pool flushes at end-of-stream.
    pub const INGEST_FLUSH_EOS: &str = "ingest.flush_eos";
    /// Counter: blocks dropped from the final partial round.
    pub const INGEST_DROPPED_BLOCKS: &str = "ingest.dropped_blocks";
    /// Counter: frames dropped from the final partial round.
    pub const INGEST_DROPPED_FRAMES: &str = "ingest.dropped_frames";

    /// Counter: batches materialized across all prefetch workers.
    pub const LOADER_BATCHES: &str = "loader.batches";
    /// Gauge: prefetch workers currently running.
    pub const LOADER_WORKERS_ACTIVE: &str = "loader.workers_active";
    /// Counter: `VideoCache` hits across workers.
    pub const LOADER_CACHE_HITS: &str = "loader.cache_hits";
    /// Counter: `VideoCache` misses across workers.
    pub const LOADER_CACHE_MISSES: &str = "loader.cache_misses";
    /// Histogram: batch-materialize latency (seconds).
    pub const LOADER_MATERIALIZE_S: &str = "loader.materialize_s";
    /// Counter: readahead warms that found the record already resident
    /// in the shared content cache (or the provider had nothing to
    /// stage — remote providers without a cache warm as no-ops).
    pub const LOADER_READAHEAD_HITS: &str = "loader.readahead_hits";
    /// Counter: readahead warms that staged new content ahead of the
    /// workers (the overlap the scheduler exists for).
    pub const LOADER_READAHEAD_MISSES: &str = "loader.readahead_misses";
    /// Counter: batch buffers served from the recycled pool.
    pub const LOADER_BUFPOOL_HITS: &str = "loader.bufpool_hits";
    /// Counter: batch buffers freshly allocated (pool empty).
    pub const LOADER_BUFPOOL_MISSES: &str = "loader.bufpool_misses";
    /// Counter name for one prefetch worker's batches.
    pub fn loader_worker_batches(worker: usize) -> String {
        format!("loader.worker{worker}.batches")
    }

    /// Counter: videos read from shard files (cache misses that hit
    /// disk).
    pub const SHARD_READS: &str = "shardstore.reads";
    /// Histogram: single-video shard read latency (seconds).
    pub const SHARD_READ_S: &str = "shardstore.read_s";
    /// Counter: `ShardPool` cache hits.
    pub const SHARD_CACHE_HITS: &str = "shardstore.cache_hits";
    /// Counter: `ShardPool` cache misses.
    pub const SHARD_CACHE_MISSES: &str = "shardstore.cache_misses";
    /// Counter: record bytes read off shard files (pread/mmap path).
    pub const SHARD_READ_BYTES: &str = "shardstore.read_bytes";
    /// Counter: record bytes staged ahead of the workers by
    /// `ShardPool::warm` (the readahead scheduler's prefetches).
    pub const SHARD_PREFETCH_BYTES: &str = "shardstore.prefetch_bytes";
    /// Histogram: wait to acquire a shard file lock (seconds).
    /// Retained for snapshot compatibility — the positional-read path
    /// (pread/mmap) is lock-free and no longer records it.
    pub const SHARD_LOCK_WAIT_S: &str = "shardstore.lock_wait_s";
    /// Counter: full-shard CRC verification scans.
    pub const SHARD_SCANS: &str = "shardstore.scans";
    /// Histogram: per-shard CRC verification scan time (seconds).
    pub const SHARD_SCAN_S: &str = "shardstore.scan_s";
    /// Counter name for reads served by one shard file.
    pub fn shard_reads(shard: usize) -> String {
        format!("shardstore.shard{shard}.reads")
    }

    /// Counter: optimizer steps taken (all ranks advance together).
    pub const TRAIN_STEPS: &str = "train.steps";
    /// Counter: real source frames consumed.
    pub const TRAIN_REAL_FRAMES: &str = "train.real_frames";
    /// Counter: block slots consumed (incl. padding).
    pub const TRAIN_SLOTS: &str = "train.slots";
    /// Gauge: padding overhead percent, `100·(1 − real/slots)`.
    pub const TRAIN_PADDING_PCT: &str = "train.padding_pct";
    /// Histogram: per-step straggler skew, `max_rank / mean_rank` of
    /// compute time (unitless, ≥ 1).
    pub const TRAIN_STEP_SKEW: &str = "train.step_skew";
    /// Histogram: gradient all-reduce latency per step (seconds).
    pub const TRAIN_ALLREDUCE_S: &str = "train.allreduce_s";
    /// Histogram name for one rank's per-step compute time.
    pub fn train_rank_step(rank: usize) -> String {
        format!("train.rank{rank}.step_s")
    }

    /// Counter: connections accepted by the serve daemon.
    pub const NET_CONNECTIONS: &str = "net.connections";
    /// Gauge: connections currently being served.
    pub const NET_CONNECTIONS_ACTIVE: &str = "net.connections_active";
    /// Counter: requests served across all connections (every opcode).
    pub const NET_REQUESTS: &str = "net.requests";
    /// Counter: response body bytes written back to clients.
    pub const NET_BYTES_SERVED: &str = "net.bytes_served";
    /// Histogram: per-request service latency, read-to-reply (seconds).
    pub const NET_REQUEST_S: &str = "net.request_s";
    /// Counter: client-side CRC re-verification failures on served
    /// records.
    pub const NET_CRC_FAILURES: &str = "net.crc_failures";
    /// Counter: client retries after transient connect/read errors.
    pub const NET_RETRIES: &str = "net.retries";

    /// Counter: replay requests completed by assault clients.
    pub const ASSAULT_REQUESTS: &str = "assault.requests";
    /// Counter: requests that failed (transport or protocol error).
    pub const ASSAULT_FAILURES: &str = "assault.failures";
    /// Counter: requests the server explicitly refused (capacity).
    pub const ASSAULT_REFUSED: &str = "assault.refused";
    /// Counter: testcases executed.
    pub const ASSAULT_CASES: &str = "assault.testcases";
    /// Counter: testcases whose evaluator verdict was FAIL.
    pub const ASSAULT_CASES_FAILED: &str = "assault.testcases_failed";
    /// Counter: payload bytes fetched by replay clients.
    pub const ASSAULT_BYTES: &str = "assault.bytes";
    /// Gauge: replay clients currently running.
    pub const ASSAULT_CLIENTS: &str = "assault.clients";
    /// Histogram: per-request replay latency (seconds), all testcases.
    pub const ASSAULT_REQUEST_S: &str = "assault.request_s";
    /// Histogram: per-client admission (connect + handshake) latency.
    pub const ASSAULT_CONNECT_S: &str = "assault.connect_s";

    /// Gauge: hosts in the fleet map (primaries + replicas).
    pub const FLEET_HOSTS: &str = "fleet.hosts";
    /// Gauge: hosts currently marked down by health tracking.
    pub const FLEET_HOSTS_DOWN: &str = "fleet.hosts_down";
    /// Counter: record fetches completed through the fleet provider.
    pub const FLEET_REQUESTS: &str = "fleet.requests";
    /// Counter: record payload bytes fetched across the fleet.
    pub const FLEET_BYTES: &str = "fleet.bytes";
    /// Counter: fetches redirected off a failing host to the next
    /// candidate (replica or probe).
    pub const FLEET_FAILOVERS: &str = "fleet.failovers";
    /// Counter: same-host retries inside the fleet fetch path.
    pub const FLEET_RETRIES: &str = "fleet.retries";
    /// Histogram: wait to check a connection out of a host pool
    /// (seconds).
    pub const FLEET_POOL_WAIT_S: &str = "fleet.pool_wait_s";
    /// Histogram: end-to-end fleet fetch latency incl. failover
    /// (seconds).
    pub const FLEET_REQUEST_S: &str = "fleet.request_s";
    /// Counter name for fetches served by one fleet host (primaries
    /// first, then replicas, in canonical order).
    pub fn fleet_host_requests(host: usize) -> String {
        format!("fleet.host{host}.requests")
    }
    /// Counter name for payload bytes served by one fleet host.
    pub fn fleet_host_bytes(host: usize) -> String {
        format!("fleet.host{host}.bytes")
    }
    /// Counter name for failovers away from one fleet host.
    pub fn fleet_host_failovers(host: usize) -> String {
        format!("fleet.host{host}.failovers")
    }
}

/// Monotonic event counter (u64, atomic).
#[derive(Debug, Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }

    fn reset(&self) {
        self.value.store(0, Ordering::Relaxed);
    }
}

/// Instantaneous f64 value (queue depth, rates, ratios). Stored as
/// bit-cast `AtomicU64`; `add` uses a CAS loop, `set`/`get` are single
/// atomic ops.
#[derive(Debug)]
pub struct Gauge {
    bits: AtomicU64,
}

impl Default for Gauge {
    fn default() -> Gauge {
        Gauge {
            bits: AtomicU64::new(0f64.to_bits()),
        }
    }
}

impl Gauge {
    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }

    pub fn add(&self, d: f64) {
        let mut cur = self.bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + d).to_bits();
            match self.bits.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn sub(&self, d: f64) {
        self.add(-d);
    }

    fn reset(&self) {
        self.set(0.0);
    }
}

/// Retained samples capped at this many entries; past the cap new
/// samples overwrite deterministically chosen slots (decimation), while
/// `count`/`sum` stay exact.
const HISTOGRAM_CAP: usize = 8192;

#[derive(Debug, Default)]
struct HistogramInner {
    samples: Vec<f64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

/// Latency histogram: retains raw samples (up to [`HISTOGRAM_CAP`]) and
/// summarizes through the same [`quantiles`] path as
/// [`Timings`](crate::metrics::Timings).
#[derive(Debug, Default)]
pub struct Histogram {
    inner: Mutex<HistogramInner>,
}

impl Histogram {
    /// Record one sample (seconds for `*_s` metrics; unitless metrics
    /// like skew ratios record the raw value).
    pub fn record(&self, v: f64) {
        let mut h = lock(&self.inner);
        if h.count == 0 {
            h.min = v;
            h.max = v;
        } else {
            h.min = h.min.min(v);
            h.max = h.max.max(v);
        }
        h.count += 1;
        h.sum += v;
        if h.samples.len() < HISTOGRAM_CAP {
            h.samples.push(v);
        } else {
            // Deterministic slot choice (Knuth multiplicative hash of
            // the running count) — no RNG on the hot path.
            let slot =
                (h.count.wrapping_mul(2654435761)) as usize % HISTOGRAM_CAP;
            h.samples[slot] = v;
        }
    }

    /// Time a closure and record its wall-clock seconds.
    pub fn time<T>(&self, f: impl FnOnce() -> T) -> T {
        let t0 = std::time::Instant::now();
        let out = f();
        self.record(t0.elapsed().as_secs_f64());
        out
    }

    /// Summary over recorded samples; `None` if nothing was recorded.
    pub fn summary(&self) -> Option<HistSummary> {
        let h = lock(&self.inner);
        let q = quantiles(&h.samples)?;
        Some(HistSummary {
            count: h.count,
            mean_s: h.sum / h.count as f64,
            min_s: h.min,
            max_s: h.max,
            p50_s: q.p50,
            p95_s: q.p95,
            p99_s: q.p99,
        })
    }

    fn reset(&self) {
        let mut h = lock(&self.inner);
        *h = HistogramInner::default();
    }
}

/// Frozen summary of one histogram.
#[derive(Debug, Clone, PartialEq)]
pub struct HistSummary {
    pub count: u64,
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
}

enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

impl Metric {
    fn kind(&self) -> &'static str {
        match self {
            Metric::Counter(_) => "counter",
            Metric::Gauge(_) => "gauge",
            Metric::Histogram(_) => "histogram",
        }
    }
}

struct Registry {
    metrics: Mutex<BTreeMap<String, Metric>>,
}

fn registry() -> &'static Registry {
    static REGISTRY: OnceLock<Registry> = OnceLock::new();
    REGISTRY.get_or_init(|| Registry {
        metrics: Mutex::new(BTreeMap::new()),
    })
}

/// Poison-tolerant lock: telemetry must keep working after an unrelated
/// panic (same policy as `dataset::shardstore`).
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

fn get_or_insert(
    name: &str,
    make: impl FnOnce() -> Metric,
    want: &'static str,
) -> Metric {
    let mut map = lock(&registry().metrics);
    let entry = map.entry(name.to_string()).or_insert_with(make);
    let found = entry.kind();
    let out = match entry {
        Metric::Counter(c) => Metric::Counter(Arc::clone(c)),
        Metric::Gauge(g) => Metric::Gauge(Arc::clone(g)),
        Metric::Histogram(h) => Metric::Histogram(Arc::clone(h)),
    };
    drop(map);
    assert!(
        found == want,
        "telemetry metric '{name}' already registered as a {found}, \
         requested as a {want}"
    );
    out
}

/// Get-or-create the counter named `name`. Hot loops should resolve the
/// handle once and reuse it.
pub fn counter(name: &str) -> Arc<Counter> {
    match get_or_insert(
        name,
        || Metric::Counter(Arc::new(Counter::default())),
        "counter",
    ) {
        Metric::Counter(c) => c,
        _ => unreachable!(),
    }
}

/// Get-or-create the gauge named `name`.
pub fn gauge(name: &str) -> Arc<Gauge> {
    match get_or_insert(
        name,
        || Metric::Gauge(Arc::new(Gauge::default())),
        "gauge",
    ) {
        Metric::Gauge(g) => g,
        _ => unreachable!(),
    }
}

/// Get-or-create the latency histogram named `name`.
pub fn histogram(name: &str) -> Arc<Histogram> {
    match get_or_insert(
        name,
        || Metric::Histogram(Arc::new(Histogram::default())),
        "histogram",
    ) {
        Metric::Histogram(h) => h,
        _ => unreachable!(),
    }
}

/// Zero every counter/gauge and clear every histogram. Existing handles
/// stay valid (the metrics are reset in place, not removed) — used by
/// `bload top` so a snapshot covers only its own pipeline, and by
/// tests.
pub fn reset() {
    let map = lock(&registry().metrics);
    for m in map.values() {
        match m {
            Metric::Counter(c) => c.reset(),
            Metric::Gauge(g) => g.reset(),
            Metric::Histogram(h) => h.reset(),
        }
    }
}

/// Point-in-time copy of the whole registry. Histograms that never
/// recorded a sample are omitted; counters and gauges appear as soon as
/// they are registered.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Snapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, f64>,
    pub histograms: BTreeMap<String, HistSummary>,
}

/// Freeze the current registry state into a [`Snapshot`].
pub fn snapshot() -> Snapshot {
    let map = lock(&registry().metrics);
    let mut snap = Snapshot::default();
    for (name, m) in map.iter() {
        match m {
            Metric::Counter(c) => {
                snap.counters.insert(name.clone(), c.get());
            }
            Metric::Gauge(g) => {
                snap.gauges.insert(name.clone(), g.get());
            }
            Metric::Histogram(h) => {
                if let Some(s) = h.summary() {
                    snap.histograms.insert(name.clone(), s);
                }
            }
        }
    }
    snap
}

impl Snapshot {
    /// Snapshot JSON schema version.
    pub const FORMAT: u64 = 1;

    /// Counter value by name (0 when absent).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Gauge value by name (0.0 when absent).
    pub fn gauge(&self, name: &str) -> f64 {
        self.gauges.get(name).copied().unwrap_or(0.0)
    }

    /// Serialize to the stable format-1 document:
    ///
    /// ```json
    /// {
    ///   "format": 1,
    ///   "counters":   { "<name>": <u64>, ... },
    ///   "gauges":     { "<name>": <f64>, ... },
    ///   "histograms": { "<name>": { "count": <u64>, "mean_s": <f64>,
    ///                               "min_s": <f64>, "max_s": <f64>,
    ///                               "p50_s": <f64>, "p95_s": <f64>,
    ///                               "p99_s": <f64> }, ... }
    /// }
    /// ```
    ///
    /// Key order is deterministic (sorted), so snapshots diff cleanly.
    pub fn to_value(&self) -> Value {
        let counters = Value::Object(
            self.counters
                .iter()
                .map(|(k, v)| (k.clone(), Value::int(*v as i64)))
                .collect(),
        );
        let gauges = Value::Object(
            self.gauges
                .iter()
                .map(|(k, v)| (k.clone(), Value::num(*v)))
                .collect(),
        );
        let histograms = Value::Object(
            self.histograms
                .iter()
                .map(|(k, s)| {
                    (
                        k.clone(),
                        Value::object(vec![
                            ("count", Value::int(s.count as i64)),
                            ("mean_s", Value::num(s.mean_s)),
                            ("min_s", Value::num(s.min_s)),
                            ("max_s", Value::num(s.max_s)),
                            ("p50_s", Value::num(s.p50_s)),
                            ("p95_s", Value::num(s.p95_s)),
                            ("p99_s", Value::num(s.p99_s)),
                        ]),
                    )
                })
                .collect(),
        );
        Value::object(vec![
            ("format", Value::int(Self::FORMAT as i64)),
            ("counters", counters),
            ("gauges", gauges),
            ("histograms", histograms),
        ])
    }

    /// Parse a format-1 document produced by [`Snapshot::to_value`].
    pub fn from_value(v: &Value) -> Result<Snapshot> {
        let fmt = v
            .get("format")
            .and_then(Value::as_usize)
            .ok_or_else(|| bad("missing 'format'"))?;
        if fmt as u64 != Self::FORMAT {
            return Err(bad(&format!("unsupported format {fmt}")));
        }
        let section = |key: &str| -> Result<&BTreeMap<String, Value>> {
            v.get(key)
                .and_then(Value::as_object)
                .ok_or_else(|| bad(&format!("missing object '{key}'")))
        };
        let mut snap = Snapshot::default();
        for (k, c) in section("counters")? {
            let n = c
                .as_f64()
                .filter(|x| *x >= 0.0 && x.fract() == 0.0)
                .ok_or_else(|| bad(&format!("counter '{k}' not a u64")))?;
            snap.counters.insert(k.clone(), n as u64);
        }
        for (k, g) in section("gauges")? {
            let n = g
                .as_f64()
                .ok_or_else(|| bad(&format!("gauge '{k}' not a number")))?;
            snap.gauges.insert(k.clone(), n);
        }
        for (k, h) in section("histograms")? {
            let f = |field: &str| -> Result<f64> {
                h.get(field).and_then(Value::as_f64).ok_or_else(|| {
                    bad(&format!("histogram '{k}' missing '{field}'"))
                })
            };
            let count = h
                .get("count")
                .and_then(Value::as_usize)
                .ok_or_else(|| bad(&format!("histogram '{k}' count")))?;
            snap.histograms.insert(
                k.clone(),
                HistSummary {
                    count: count as u64,
                    mean_s: f("mean_s")?,
                    min_s: f("min_s")?,
                    max_s: f("max_s")?,
                    p50_s: f("p50_s")?,
                    p95_s: f("p95_s")?,
                    p99_s: f("p99_s")?,
                },
            );
        }
        Ok(snap)
    }
}

fn bad(msg: &str) -> Error {
    Error::Bench(format!("telemetry snapshot: {msg}"))
}

/// Add `n` to the counter named `$name` (cold-path convenience; hot
/// loops should hold an `Arc` from [`counter`](crate::telemetry::counter)).
#[macro_export]
macro_rules! counter_add {
    ($name:expr, $n:expr) => {
        $crate::telemetry::counter($name).add($n)
    };
}

/// Increment the counter named `$name` by one.
#[macro_export]
macro_rules! counter_inc {
    ($name:expr) => {
        $crate::telemetry::counter($name).inc()
    };
}

/// Set the gauge named `$name` to `$v`.
#[macro_export]
macro_rules! gauge_set {
    ($name:expr, $v:expr) => {
        $crate::telemetry::gauge($name).set($v)
    };
}

/// Record `$secs` into the histogram named `$name`.
#[macro_export]
macro_rules! histogram_record {
    ($name:expr, $secs:expr) => {
        $crate::telemetry::histogram($name).record($secs)
    };
}

/// Serializes tests that assert exact global-registry state (or call
/// the global [`reset`]) — the registry is process-wide and `cargo
/// test` threads would otherwise race each other. Shared by this
/// module's tests and by telemetry-asserting tests elsewhere in the
/// crate (`harness::observe`, bench-report embedding).
#[cfg(test)]
pub(crate) fn test_guard() -> MutexGuard<'static, ()> {
    static GUARD: OnceLock<Mutex<()>> = OnceLock::new();
    lock(GUARD.get_or_init(|| Mutex::new(())))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handles_are_shared_by_name() {
        let a = counter("test.telemetry.shared");
        let b = counter("test.telemetry.shared");
        assert!(Arc::ptr_eq(&a, &b));
        let g1 = gauge("test.telemetry.shared_gauge");
        let g2 = gauge("test.telemetry.shared_gauge");
        assert!(Arc::ptr_eq(&g1, &g2));
    }

    #[test]
    #[should_panic(expected = "already registered as a counter")]
    fn cross_kind_registration_panics() {
        counter("test.telemetry.kind_clash");
        gauge("test.telemetry.kind_clash");
    }

    #[test]
    fn concurrent_increments_are_exact() {
        let _g = test_guard();
        let name = "test.telemetry.concurrent";
        let c = counter(name);
        c.reset();
        let threads: Vec<_> = (0..8)
            .map(|_| {
                let c = counter(name);
                std::thread::spawn(move || {
                    for _ in 0..10_000 {
                        c.inc();
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(c.get(), 80_000);
    }

    #[test]
    fn gauge_add_is_atomic_under_contention() {
        let _g = test_guard();
        let name = "test.telemetry.gauge_contended";
        let g = gauge(name);
        g.set(0.0);
        let threads: Vec<_> = (0..4)
            .map(|_| {
                let g = gauge(name);
                std::thread::spawn(move || {
                    for _ in 0..1_000 {
                        g.add(1.0);
                        g.sub(1.0);
                        g.add(1.0);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert!((g.get() - 4_000.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_summary_matches_timings_path() {
        use std::time::Duration;
        let h = histogram("test.telemetry.hist_match");
        let mut t = crate::metrics::Timings::new();
        for ms in 1..=100u64 {
            let s = ms as f64 / 1e3;
            h.record(s);
            t.record("x", Duration::from_secs_f64(s));
        }
        let s = h.summary().unwrap();
        assert_eq!(s.count, 100);
        // Same percentile path as Timings — identical answers.
        assert_eq!(s.p50_s, t.p50_seconds("x"));
        assert_eq!(s.p95_s, t.p95_seconds("x"));
        assert_eq!(s.p99_s, t.p99_seconds("x"));
        assert!((s.mean_s - 0.0505).abs() < 1e-9);
        assert_eq!(s.min_s, 0.001);
        assert_eq!(s.max_s, 0.100);
    }

    #[test]
    fn histogram_cap_decimates_but_keeps_exact_count() {
        let h = Histogram::default();
        for i in 0..(HISTOGRAM_CAP as u64 + 500) {
            h.record(i as f64);
        }
        let s = h.summary().unwrap();
        assert_eq!(s.count, HISTOGRAM_CAP as u64 + 500);
        assert_eq!(s.min_s, 0.0);
        assert_eq!(s.max_s, (HISTOGRAM_CAP as u64 + 499) as f64);
        assert_eq!(lock(&h.inner).samples.len(), HISTOGRAM_CAP);
    }

    #[test]
    fn snapshot_roundtrips_through_jsonio() {
        let _g = test_guard();
        counter("test.telemetry.snap_counter").add(42);
        gauge("test.telemetry.snap_gauge").set(2.5);
        let h = histogram("test.telemetry.snap_hist");
        h.record(0.001);
        h.record(0.003);
        let snap = snapshot();
        assert_eq!(snap.counter("test.telemetry.snap_counter") % 42, 0);
        let text = crate::jsonio::to_string_pretty(&snap.to_value());
        let parsed = crate::jsonio::parse(&text).unwrap();
        let back = Snapshot::from_value(&parsed).unwrap();
        assert_eq!(snap, back);
    }

    #[test]
    fn from_value_rejects_bad_documents() {
        assert!(Snapshot::from_value(&Value::Null).is_err());
        let wrong_fmt = Value::object(vec![
            ("format", Value::int(99)),
            ("counters", Value::object(vec![])),
            ("gauges", Value::object(vec![])),
            ("histograms", Value::object(vec![])),
        ]);
        assert!(Snapshot::from_value(&wrong_fmt).is_err());
        let missing = Value::object(vec![("format", Value::int(1))]);
        assert!(Snapshot::from_value(&missing).is_err());
    }

    #[test]
    fn reset_zeroes_in_place() {
        let _g = test_guard();
        let c = counter("test.telemetry.reset_counter");
        let h = histogram("test.telemetry.reset_hist");
        c.add(7);
        h.record(1.0);
        reset();
        assert_eq!(c.get(), 0);
        assert!(h.summary().is_none());
        // The handle survives a reset and keeps counting.
        c.inc();
        assert_eq!(c.get(), 1);
    }

    #[test]
    fn macros_compile_and_record() {
        let _g = test_guard();
        crate::counter_inc!("test.telemetry.macro_counter");
        crate::counter_add!("test.telemetry.macro_counter", 2);
        crate::gauge_set!("test.telemetry.macro_gauge", 1.5);
        crate::histogram_record!("test.telemetry.macro_hist", 0.25);
        let snap = snapshot();
        assert!(snap.counter("test.telemetry.macro_counter") >= 3);
        assert!(snap.gauge("test.telemetry.macro_gauge") >= 1.5);
        assert!(snap.histograms.contains_key("test.telemetry.macro_hist"));
    }
}
