//! The training loop: simulated multi-rank DDP over PJRT executables.

pub mod schedule;
pub mod trainer;

pub use schedule::LrSchedule;
pub use trainer::{EpochStats, Trainer};
