//! Learning-rate schedule: linear warmup → constant.

/// Linear warmup to `base_lr` over `warmup_steps`, then constant.
#[derive(Debug, Clone, Copy)]
pub struct LrSchedule {
    pub base_lr: f64,
    pub warmup_steps: usize,
}

impl LrSchedule {
    pub fn new(base_lr: f64, warmup_steps: usize) -> LrSchedule {
        LrSchedule {
            base_lr,
            warmup_steps,
        }
    }

    /// LR at global step `step` (0-based).
    pub fn at(&self, step: u64) -> f64 {
        if self.warmup_steps == 0 || step >= self.warmup_steps as u64 {
            self.base_lr
        } else {
            self.base_lr * (step + 1) as f64 / self.warmup_steps as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_ramps_then_holds() {
        let s = LrSchedule::new(0.1, 10);
        assert!((s.at(0) - 0.01).abs() < 1e-12);
        assert!((s.at(4) - 0.05).abs() < 1e-12);
        assert!((s.at(9) - 0.1).abs() < 1e-12);
        assert!((s.at(100) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn zero_warmup_is_constant() {
        let s = LrSchedule::new(0.2, 0);
        assert_eq!(s.at(0), 0.2);
    }
}
