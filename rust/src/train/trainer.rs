//! The DDP trainer: per-rank gradient steps through the PJRT engine,
//! bucketed all-reduce, single parameter update.
//!
//! Ranks execute *sequentially* on the CPU client (the simulator model —
//! DESIGN.md §1): per step, each rank runs `grad_step` on its own batch
//! and its own recurrent state; gradients are then mean-reduced with the
//! configured collective and applied once (mathematically identical to
//! PyTorch DDP, where every rank applies the same averaged gradient).
//! Timing reports both measured wall-clock and the *simulated parallel*
//! time (`Σ_steps max_rank(compute)`), which is what an 8-GPU box would
//! observe.

use std::sync::Arc;

use crate::config::{DdpConfig, EvalConfig, LoaderConfig, TrainConfig};
use crate::dataset::Split;
use crate::ddp::collective::by_name;
use crate::ddp::GradSynchronizer;
use crate::error::{Error, Result};
use crate::eval::RecallAccumulator;
use crate::loader::{DataLoader, DataLoaderBuilder};
use crate::log_info;
use crate::metrics::Timings;
use crate::model::StateManager;
use crate::packing::{Block, PackedDataset};
use crate::runtime::Engine;
use crate::telemetry::{self, names};
use crate::train::LrSchedule;

/// Per-epoch training statistics.
#[derive(Debug, Clone)]
pub struct EpochStats {
    pub epoch: u64,
    pub steps: usize,
    pub mean_loss: f64,
    pub final_loss: f64,
    /// Wall-clock of the epoch (ranks serialized on this CPU).
    pub wall_s: f64,
    /// Simulated 8-GPU parallel time: Σ_steps max over ranks of compute.
    pub parallel_s: f64,
    /// Real source frames consumed.
    pub real_frames: usize,
    /// Total slots (incl. padding) — the compute actually spent.
    pub slots: usize,
}

/// Multi-rank DDP trainer over one [`Engine`].
pub struct Trainer {
    pub engine: Engine,
    pub params: Vec<f32>,
    pub mom: Vec<f32>,
    sync: GradSynchronizer,
    states: Vec<StateManager>,
    lr: LrSchedule,
    train_cfg: TrainConfig,
    ddp_cfg: DdpConfig,
    loader_cfg: LoaderConfig,
    pub timings: Timings,
    pub global_step: u64,
    pub history: Vec<EpochStats>,
    nan_streak: usize,
    seed: u64,
}

impl Trainer {
    pub fn new(engine: Engine, train_cfg: TrainConfig, ddp_cfg: DdpConfig,
               loader_cfg: LoaderConfig, seed: u64) -> Result<Trainer> {
        if engine.spec.batch != ddp_cfg.batch_per_rank {
            return Err(Error::Train(format!(
                "artifact profile '{}' was compiled for B={}, but \
                 ddp.batch_per_rank={}; rebuild artifacts or fix the config",
                engine.spec.name, engine.spec.batch, ddp_cfg.batch_per_rank
            )));
        }
        let params = engine.spec.load_init_params()?;
        let mom = vec![0.0; params.len()];
        let states = (0..ddp_cfg.ranks)
            .map(|_| {
                StateManager::new(engine.spec.state_dim,
                                  train_cfg.carry_state)
            })
            .collect();
        let sync = GradSynchronizer::new(by_name(&ddp_cfg.allreduce),
                                         ddp_cfg.bucket_elems);
        Ok(Trainer {
            lr: LrSchedule::new(train_cfg.lr, train_cfg.warmup_steps),
            engine,
            params,
            mom,
            sync,
            states,
            train_cfg,
            ddp_cfg,
            loader_cfg,
            timings: Timings::new(),
            global_step: 0,
            history: Vec::new(),
            nan_streak: 0,
            seed,
        })
    }

    /// Train one epoch over `packed`; returns the epoch stats.
    pub fn train_epoch(&mut self, split: &Arc<Split>,
                       packed: &Arc<PackedDataset>, epoch: u64)
                       -> Result<EpochStats> {
        self.train_epoch_capped(split, packed, epoch, 0)
    }

    /// Train one epoch, stopping after `max_steps` steps (0 = whole
    /// epoch). Used by the full-geometry timing harness to cap the ~4×
    /// naive-padding arm and extrapolate.
    pub fn train_epoch_capped(&mut self, split: &Arc<Split>,
                              packed: &Arc<PackedDataset>, epoch: u64,
                              max_steps: usize) -> Result<EpochStats> {
        let ranks = self.ddp_cfg.ranks;
        let batch = self.ddp_cfg.batch_per_rank;
        let builder = DataLoaderBuilder::from_config(&self.loader_cfg)
            .seed(self.seed)
            .batch(batch);
        let mut loaders: Vec<DataLoader> = (0..ranks)
            .map(|r| {
                builder.clone().shard(ranks, r).planned(
                    Arc::clone(split), Arc::clone(packed), epoch)
            })
            .collect::<Result<_>>()?;
        let mut steps =
            loaders[0].steps().expect("planned loaders know their length");
        if max_steps > 0 {
            steps = steps.min(max_steps);
        }
        if steps == 0 {
            return Err(Error::Train(format!(
                "epoch {epoch}: no full batches ({} blocks / {ranks} ranks \
                 / batch {batch})",
                packed.blocks.len()
            )));
        }
        for st in &mut self.states {
            st.reset();
        }

        let epoch_t0 = std::time::Instant::now();
        let mut parallel_s = 0.0f64;
        let mut loss_sum = 0.0f64;
        let mut final_loss = 0.0f64;
        let mut real_frames = 0usize;
        let mut slots = 0usize;
        let mut grads: Vec<Vec<f32>> = Vec::with_capacity(ranks);
        // Telemetry handles resolved once per epoch (atomic-only loop).
        let t_steps = telemetry::counter(names::TRAIN_STEPS);
        let t_real = telemetry::counter(names::TRAIN_REAL_FRAMES);
        let t_slots = telemetry::counter(names::TRAIN_SLOTS);
        let t_skew = telemetry::histogram(names::TRAIN_STEP_SKEW);
        let t_allreduce = telemetry::histogram(names::TRAIN_ALLREDUCE_S);
        let t_rank_step: Vec<_> = (0..ranks)
            .map(|r| telemetry::histogram(&names::train_rank_step(r)))
            .collect();

        for step in 0..steps {
            grads.clear();
            let mut step_max_compute = 0.0f64;
            let mut step_sum_compute = 0.0f64;
            let mut step_loss = 0.0f64;
            // Upload parameters once per step; every rank executes against
            // the same literal (DDP keeps replicas identical — §Perf L3).
            let params_lit = self.engine.params_literal(&self.params)?;
            for rank in 0..ranks {
                let batch_data = self
                    .timings
                    .time("loader.next", || loaders[rank].next())
                    .ok_or_else(|| {
                        Error::Train(format!(
                            "rank {rank} ran out of batches at step {step}"
                        ))
                    })??;
                let blocks: Vec<&Block> = batch_data
                    .block_ids
                    .iter()
                    .map(|&i| &packed.blocks[i])
                    .collect();
                let state_in =
                    self.states[rank].state_in(&batch_data, &blocks);
                let t0 = std::time::Instant::now();
                let out = self.engine.grad_step_lit(&params_lit, &batch_data,
                                                    &state_in)?;
                let dt = t0.elapsed().as_secs_f64();
                self.timings
                    .record("compute.grad_step",
                            std::time::Duration::from_secs_f64(dt));
                t_rank_step[rank].record(dt);
                step_max_compute = step_max_compute.max(dt);
                step_sum_compute += dt;
                self.states[rank].absorb(&out.state_out, &blocks);
                step_loss += out.loss as f64;
                real_frames += batch_data.real_frames;
                slots += batch_data.slots;
                grads.push(out.grads);
            }
            parallel_s += step_max_compute;
            t_steps.inc();
            if step_sum_compute > 0.0 {
                // Straggler skew: slowest rank vs the step's mean rank
                // compute (1.0 = perfectly balanced).
                t_skew.record(
                    step_max_compute * ranks as f64 / step_sum_compute,
                );
            }

            // Gradient synchronization (all ranks' grads -> mean).
            let allreduce_t0 = std::time::Instant::now();
            self.timings.time("comm.allreduce", || {
                self.sync.sync(&mut grads)
            });
            t_allreduce.record(allreduce_t0.elapsed().as_secs_f64());

            let lr = self.lr.at(self.global_step) as f32;
            let momentum = self.train_cfg.momentum as f32;
            let (params, mom) = (&mut self.params, &mut self.mom);
            let engine = &self.engine;
            let g0 = &grads[0];
            self.timings.time("compute.apply_update", || {
                engine.apply_update(params, mom, g0, lr, momentum)
            })?;

            let mean_step_loss = step_loss / ranks as f64;
            loss_sum += mean_step_loss;
            final_loss = mean_step_loss;
            if !mean_step_loss.is_finite() {
                self.nan_streak += 1;
                if self.nan_streak >= self.train_cfg.nan_tolerance {
                    return Err(Error::Train(format!(
                        "loss non-finite for {} consecutive steps \
                         (step {})",
                        self.nan_streak, self.global_step
                    )));
                }
            } else {
                self.nan_streak = 0;
            }
            self.global_step += 1;
            if self.train_cfg.log_every > 0
                && (step + 1) % self.train_cfg.log_every == 0
            {
                log_info!(
                    "epoch {epoch} step {}/{steps} loss {mean_step_loss:.4} \
                     lr {lr:.4}",
                    step + 1
                );
            }
        }
        // Dropping the loaders joins their workers — in the capped case
        // this abandons the epoch mid-stream, which the loader's Drop
        // handles without leaking threads.
        drop(loaders);
        t_real.add(real_frames as u64);
        t_slots.add(slots as u64);
        if slots > 0 {
            telemetry::gauge(names::TRAIN_PADDING_PCT)
                .set(100.0 * (1.0 - real_frames as f64 / slots as f64));
        }
        let stats = EpochStats {
            epoch,
            steps,
            mean_loss: loss_sum / steps as f64,
            final_loss,
            wall_s: epoch_t0.elapsed().as_secs_f64(),
            parallel_s,
            real_frames,
            slots,
        };
        log_info!(
            "epoch {epoch} done: steps={} loss={:.4} wall={:.1}s \
             parallel={:.1}s frames={} slots={}",
            stats.steps, stats.mean_loss, stats.wall_s, stats.parallel_s,
            stats.real_frames, stats.slots
        );
        self.history.push(stats.clone());
        Ok(stats)
    }

    /// Evaluate recall@K over a packed test split (single rank, no grads).
    pub fn evaluate(&mut self, split: &Arc<Split>,
                    packed: &Arc<PackedDataset>, eval_cfg: &EvalConfig)
                    -> Result<f64> {
        let spec = &self.engine.spec;
        let b = spec.batch;
        let mut loader = DataLoaderBuilder::from_config(&self.loader_cfg)
            .shuffle(false)
            .seed(self.seed)
            .batch(b)
            .planned(Arc::clone(split), Arc::clone(packed), 0)?;
        let mut acc = RecallAccumulator::new();
        let mut state_mgr =
            StateManager::new(spec.state_dim, self.train_cfg.carry_state);
        let params_lit = self.engine.params_literal(&self.params)?;
        while let Some(batch) = loader.next() {
            let batch = batch?;
            let blocks: Vec<&Block> = batch
                .block_ids
                .iter()
                .map(|&i| &packed.blocks[i])
                .collect();
            let state_in = state_mgr.state_in(&batch, &blocks);
            let out = self.engine.infer_step_lit(&params_lit, &batch,
                                                 &state_in)?;
            state_mgr.absorb(&out.state_out, &blocks);
            acc.push_batch(&out.logits, &batch.labels, &batch.frame_mask,
                           b, spec.block_len, spec.objects, spec.classes,
                           eval_cfg.recall_k);
        }
        loader.shutdown();
        if acc.frames == 0 {
            return Err(Error::Train("evaluation saw zero frames".into()));
        }
        Ok(acc.recall_pct())
    }
}
