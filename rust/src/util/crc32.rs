//! CRC-32 (IEEE 802.3 polynomial, the zlib/PNG variant) for the dataset
//! store's integrity footer and every `.blds` shard record.
//!
//! The kernel is *slice-by-16*: sixteen 256-entry lookup tables fold 16
//! input bytes per loop iteration instead of one, which matters because
//! shard replay CRC-verifies every record it reads off disk. Digests
//! are bit-for-bit identical to the classic one-table byte-at-a-time
//! form (the original kernel is retained as the property-test
//! reference), so checksums written by older builds keep verifying.
//!
//! # Examples
//!
//! ```
//! use bload::util::crc32::{crc32, Hasher};
//!
//! // One-shot digest of a whole slice.
//! assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
//!
//! // Incremental hashing at arbitrary split points yields the same
//! // digest.
//! let mut h = Hasher::new();
//! h.update(b"1234");
//! h.update(b"56789");
//! assert_eq!(h.finalize(), crc32(b"123456789"));
//! ```

use std::sync::OnceLock;

/// Reflected IEEE 802.3 generator polynomial.
const POLY: u32 = 0xEDB8_8320;

/// Bytes folded per main-loop iteration (and lookup-table count).
const SLICES: usize = 16;

static TABLES: OnceLock<Box<[[u32; 256]; SLICES]>> = OnceLock::new();

/// `tables()[k][b]` is the CRC of byte `b` followed by `k` zero bytes;
/// table 0 is the classic single-table kernel's table.
fn tables() -> &'static [[u32; 256]; SLICES] {
    TABLES.get_or_init(|| {
        let mut t = Box::new([[0u32; 256]; SLICES]);
        for i in 0..256u32 {
            let mut c = i;
            for _ in 0..8 {
                c = if c & 1 != 0 { POLY ^ (c >> 1) } else { c >> 1 };
            }
            t[0][i as usize] = c;
        }
        for k in 1..SLICES {
            for i in 0..256 {
                let prev = t[k - 1][i];
                t[k][i] = (prev >> 8) ^ t[0][(prev & 0xFF) as usize];
            }
        }
        t
    })
}

/// One-shot CRC-32 of a byte slice.
pub fn crc32(data: &[u8]) -> u32 {
    let mut h = Hasher::new();
    h.update(data);
    h.finalize()
}

/// Incremental CRC-32 hasher.
///
/// `update` may be called at arbitrary boundaries; the digest only
/// depends on the concatenated byte stream.
#[derive(Debug, Clone)]
pub struct Hasher {
    state: u32,
}

impl Default for Hasher {
    fn default() -> Self {
        Self::new()
    }
}

impl Hasher {
    pub fn new() -> Self {
        Hasher { state: 0xFFFF_FFFF }
    }

    pub fn update(&mut self, data: &[u8]) {
        let t = tables();
        let mut state = self.state;
        let mut rest = data;
        // Slice-by-16 main loop: fold the 4 running-state bytes through
        // tables 15..12 and the next 12 raw input bytes through 11..0,
        // advancing the CRC by 16 bytes per iteration.
        while rest.len() >= SLICES {
            let (chunk, tail) = rest.split_at(SLICES);
            state ^= u32::from_le_bytes([chunk[0], chunk[1], chunk[2],
                                         chunk[3]]);
            let mut next = t[15][(state & 0xFF) as usize]
                ^ t[14][((state >> 8) & 0xFF) as usize]
                ^ t[13][((state >> 16) & 0xFF) as usize]
                ^ t[12][(state >> 24) as usize];
            for (j, &b) in chunk[4..].iter().enumerate() {
                next ^= t[11 - j][b as usize];
            }
            state = next;
            rest = tail;
        }
        // Byte-at-a-time tail (< 16 bytes).
        for &b in rest {
            state = t[0][((state ^ b as u32) & 0xFF) as usize]
                ^ (state >> 8);
        }
        self.state = state;
    }

    pub fn finalize(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The pre-slice-by-16 kernel, verbatim: one table, one byte per
    /// step. The equivalence property tests below pin the new kernel
    /// to this reference so on-disk checksums can never drift.
    fn crc32_bytewise(data: &[u8]) -> u32 {
        let t = tables();
        let mut state = 0xFFFF_FFFFu32;
        for &b in data {
            state = t[0][((state ^ b as u32) & 0xFF) as usize]
                ^ (state >> 8);
        }
        state ^ 0xFFFF_FFFF
    }

    fn xorshift(s: &mut u64) -> u64 {
        *s ^= *s << 13;
        *s ^= *s >> 7;
        *s ^= *s << 17;
        *s
    }

    #[test]
    fn known_vectors() {
        // Standard IEEE CRC-32 test vectors.
        assert_eq!(crc32(b""), 0x0000_0000);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"),
                   0x414F_A339);
    }

    #[test]
    fn incremental_equals_oneshot() {
        let data = b"hello world, hello crc";
        let mut h = Hasher::new();
        h.update(&data[..7]);
        h.update(&data[7..]);
        assert_eq!(h.finalize(), crc32(data));
    }

    #[test]
    fn detects_single_bit_flip() {
        let mut data = vec![0xAAu8; 1024];
        let base = crc32(&data);
        data[512] ^= 0x01;
        assert_ne!(base, crc32(&data));
    }

    #[test]
    fn slice_by_16_matches_bytewise_reference() {
        // Cover every alignment class around the 16-byte fold width,
        // plus large buffers.
        let mut seed = 0x243F_6A88_85A3_08D3u64;
        for len in [0usize, 1, 3, 15, 16, 17, 31, 32, 33, 63, 64, 100,
                    255, 256, 1000, 4096 + 3] {
            let data: Vec<u8> = (0..len)
                .map(|_| (xorshift(&mut seed) & 0xFF) as u8)
                .collect();
            assert_eq!(crc32(&data), crc32_bytewise(&data),
                       "len {len}");
        }
    }

    #[test]
    fn random_split_points_match_reference() {
        // Feed one stream through `update` at arbitrary boundaries —
        // the digest must not depend on where the splits fall.
        let mut seed = 0x9E37_79B9_7F4A_7C15u64;
        let data: Vec<u8> = (0..4097)
            .map(|_| (xorshift(&mut seed) & 0xFF) as u8)
            .collect();
        let want = crc32_bytewise(&data);
        for _ in 0..32 {
            let mut h = Hasher::new();
            let mut at = 0usize;
            while at < data.len() {
                let step = 1 + (xorshift(&mut seed) % 97) as usize;
                let end = (at + step).min(data.len());
                h.update(&data[at..end]);
                at = end;
            }
            assert_eq!(h.finalize(), want);
        }
    }
}
