//! CRC-32 (IEEE 802.3 polynomial, the zlib/PNG variant) for the dataset
//! store's integrity footer. Table-driven, computed once at first use.

use std::sync::OnceLock;

static TABLE: OnceLock<[u32; 256]> = OnceLock::new();

fn table() -> &'static [u32; 256] {
    TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    })
}

/// One-shot CRC-32 of a byte slice.
pub fn crc32(data: &[u8]) -> u32 {
    let mut h = Hasher::new();
    h.update(data);
    h.finalize()
}

/// Incremental CRC-32 hasher.
#[derive(Debug, Clone)]
pub struct Hasher {
    state: u32,
}

impl Default for Hasher {
    fn default() -> Self {
        Self::new()
    }
}

impl Hasher {
    pub fn new() -> Self {
        Hasher { state: 0xFFFF_FFFF }
    }

    pub fn update(&mut self, data: &[u8]) {
        let t = table();
        for &b in data {
            self.state = t[((self.state ^ b as u32) & 0xFF) as usize]
                ^ (self.state >> 8);
        }
    }

    pub fn finalize(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Standard IEEE CRC-32 test vectors.
        assert_eq!(crc32(b""), 0x0000_0000);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"),
                   0x414F_A339);
    }

    #[test]
    fn incremental_equals_oneshot() {
        let data = b"hello world, hello crc";
        let mut h = Hasher::new();
        h.update(&data[..7]);
        h.update(&data[7..]);
        assert_eq!(h.finalize(), crc32(data));
    }

    #[test]
    fn detects_single_bit_flip() {
        let mut data = vec![0xAAu8; 1024];
        let base = crc32(&data);
        data[512] ^= 0x01;
        assert_ne!(base, crc32(&data));
    }
}
