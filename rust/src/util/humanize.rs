//! Human-readable formatting of durations, counts and byte sizes for CLI
//! and bench output.

use std::time::Duration;

/// `1234567` → `"1,234,567"`.
pub fn commas(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

/// Compact duration: `"1.23s"`, `"45.1ms"`, `"820µs"`, `"2m03s"`.
pub fn duration(d: Duration) -> String {
    let secs = d.as_secs_f64();
    if secs >= 60.0 {
        let m = (secs / 60.0).floor() as u64;
        format!("{m}m{:04.1}s", secs - m as f64 * 60.0)
    } else if secs >= 1.0 {
        format!("{secs:.2}s")
    } else if secs >= 1e-3 {
        format!("{:.1}ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.0}µs", secs * 1e6)
    } else {
        format!("{:.0}ns", secs * 1e9)
    }
}

/// Bytes with binary units: `"1.50 MiB"`.
pub fn bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Rate: items per second with SI prefixes (`"3.4M/s"`).
pub fn rate(items: f64, seconds: f64) -> String {
    let r = if seconds > 0.0 { items / seconds } else { 0.0 };
    if r >= 1e9 {
        format!("{:.2}G/s", r / 1e9)
    } else if r >= 1e6 {
        format!("{:.2}M/s", r / 1e6)
    } else if r >= 1e3 {
        format!("{:.2}k/s", r / 1e3)
    } else {
        format!("{r:.1}/s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn commas_groups() {
        assert_eq!(commas(0), "0");
        assert_eq!(commas(999), "999");
        assert_eq!(commas(1000), "1,000");
        assert_eq!(commas(534831), "534,831");
        assert_eq!(commas(1234567890), "1,234,567,890");
    }

    #[test]
    fn duration_scales() {
        assert_eq!(duration(Duration::from_secs(125)), "2m05.0s");
        assert_eq!(duration(Duration::from_millis(1500)), "1.50s");
        assert_eq!(duration(Duration::from_micros(4200)), "4.2ms");
        assert_eq!(duration(Duration::from_nanos(900)), "900ns");
    }

    #[test]
    fn bytes_units() {
        assert_eq!(bytes(512), "512 B");
        assert_eq!(bytes(1536), "1.50 KiB");
        assert_eq!(bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn rates() {
        assert_eq!(rate(1000.0, 1.0), "1.00k/s");
        assert_eq!(rate(0.0, 0.0), "0.0/s");
        assert_eq!(rate(2_500_000.0, 1.0), "2.50M/s");
    }
}
