//! Small self-contained utilities shared across the crate.
//!
//! This environment has no third-party utility crates available offline, so
//! the pieces a data-pipeline system normally pulls in (a seedable PRNG,
//! percentile stats, CRC32, top-k selection, humanized units) live here,
//! each with unit tests.

pub mod crc32;
pub mod humanize;
pub mod rng;
pub mod stats;
pub mod tensor;
pub mod topk;

pub use rng::Rng;
