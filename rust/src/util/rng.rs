//! Deterministic PRNG: SplitMix64 seeding + xoshiro256\*\* core.
//!
//! Every stochastic component in the pipeline (dataset synthesis, the
//! `Random*` draw in the BLoad packer, shuffling, DDP jitter) takes an
//! explicit seed so experiments are exactly reproducible. No `rand` crate
//! exists in this offline environment; this is the standard xoshiro256\*\*
//! generator (Blackman & Vigna), seeded via SplitMix64 as its authors
//! recommend.

/// xoshiro256\*\* seeded from a single `u64` via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a seed. Identical seeds ⇒ identical streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream (for per-worker/per-epoch seeds).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Uses Lemire's unbiased multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0)");
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n {
                return (m >> 64) as u64;
            }
            // Rejection zone: only loop when lo < n and biased.
            let threshold = n.wrapping_neg() % n;
            if lo >= threshold {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform `usize` in `[lo, hi)` (half-open).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "Rng::range empty [{lo}, {hi})");
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Standard normal via Box–Muller (cached second value not kept —
    /// simplicity over speed; this is not on the hot path).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (std::f64::consts::TAU * u2).cos();
            }
        }
    }

    /// Bernoulli draw.
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted: zero total weight");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit: {seen:?}");
    }

    #[test]
    fn f64_uniform_mean() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.07, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<u32> = (0..57).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..57).collect::<Vec<_>>());
        assert_ne!(v, (0..57).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::new(5);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..4000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.5, "ratio={ratio}");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::new(1234);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        let av: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let bv: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(av, bv);
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(2);
        for _ in 0..500 {
            let x = r.range(5, 9);
            assert!((5..9).contains(&x));
        }
    }
}
