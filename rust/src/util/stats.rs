//! Streaming and batch statistics: mean/std/min/max, percentiles,
//! histograms. Used by the bench harness, the metrics reporters and the
//! dataset calibration checks.

/// Batch summary of a sample of `f64`s.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute a summary; returns `None` for an empty sample.
    pub fn of(xs: &[f64]) -> Option<Summary> {
        if xs.is_empty() {
            return None;
        }
        let n = xs.len() as f64;
        let mean = xs.iter().sum::<f64>() / n;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n;
        let mut sorted: Vec<f64> = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Some(Summary {
            count: xs.len(),
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: *sorted.last().unwrap(),
            p50: percentile_sorted(&sorted, 50.0),
            p90: percentile_sorted(&sorted, 90.0),
            p99: percentile_sorted(&sorted, 99.0),
        })
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], pct: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&pct));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = pct / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Welford online mean/variance accumulator (single pass, numerically
/// stable) — used by long-running counters in the trainer and loader.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Fixed-bin histogram over `[lo, hi)` with overflow/underflow buckets.
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    bins: Vec<u64>,
    underflow: u64,
    overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Histogram {
            lo,
            hi,
            bins: vec![0; nbins],
            underflow: 0,
            overflow: 0,
        }
    }

    pub fn push(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let idx = ((x - self.lo) / (self.hi - self.lo)
                * self.bins.len() as f64) as usize;
            let idx = idx.min(self.bins.len() - 1);
            self.bins[idx] += 1;
        }
    }

    pub fn bins(&self) -> &[u64] {
        &self.bins
    }

    pub fn total(&self) -> u64 {
        self.bins.iter().sum::<u64>() + self.underflow + self.overflow
    }

    /// Compact ASCII sparkline of the histogram (for CLI inspection).
    pub fn sparkline(&self) -> String {
        const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let max = self.bins.iter().copied().max().unwrap_or(0).max(1);
        self.bins
            .iter()
            .map(|&b| GLYPHS[(b as usize * (GLYPHS.len() - 1)) / max as usize])
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(s.count, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.p50 - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_empty_is_none() {
        assert!(Summary::of(&[]).is_none());
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile_sorted(&xs, 50.0) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&xs, 0.0), 0.0);
        assert_eq!(percentile_sorted(&xs, 100.0), 10.0);
    }

    #[test]
    fn welford_matches_batch() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 3.0).collect();
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let s = Summary::of(&xs).unwrap();
        assert!((w.mean() - s.mean).abs() < 1e-9);
        assert!((w.std() - s.std).abs() < 1e-9);
        assert_eq!(w.min(), s.min);
        assert_eq!(w.max(), s.max);
    }

    #[test]
    fn histogram_counts_and_overflow() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.push(i as f64 + 0.5);
        }
        h.push(-1.0);
        h.push(42.0);
        assert_eq!(h.bins(), &[1u64; 10][..]);
        assert_eq!(h.total(), 12);
        assert_eq!(h.sparkline().chars().count(), 10);
    }
}
