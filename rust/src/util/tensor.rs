//! A minimal dense f32 tensor for host-side staging.
//!
//! This is *not* a compute library — all heavy math runs inside the AOT'd
//! XLA executables. [`Tensor`] exists to carry shaped `f32` buffers between
//! the loader, the DDP gradient exchange and the PJRT literal conversion,
//! with shape checking at the boundaries.

use crate::error::{Error, Result};

/// Dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Zero-filled tensor.
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    /// Constant-filled tensor.
    pub fn full(shape: &[usize], value: f32) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![value; shape.iter().product()],
        }
    }

    /// Wrap an existing buffer; the length must match the shape product.
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Tensor> {
        let want: usize = shape.iter().product();
        if want != data.len() {
            return Err(Error::Runtime(format!(
                "Tensor::from_vec: shape {shape:?} wants {want} elements, \
                 buffer has {}",
                data.len()
            )));
        }
        Ok(Tensor {
            shape: shape.to_vec(),
            data,
        })
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Row-major linear offset of a multi-index (debug-checked).
    pub fn offset(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.shape.len());
        let mut off = 0;
        for (d, (&i, &s)) in idx.iter().zip(&self.shape).enumerate() {
            debug_assert!(i < s, "index {i} out of bounds for dim {d} ({s})");
            off = off * s + i;
        }
        off
    }

    pub fn get(&self, idx: &[usize]) -> f32 {
        self.data[self.offset(idx)]
    }

    pub fn set(&mut self, idx: &[usize], v: f32) {
        let o = self.offset(idx);
        self.data[o] = v;
    }

    /// Reinterpret with a new shape of identical element count.
    pub fn reshape(mut self, shape: &[usize]) -> Result<Tensor> {
        let want: usize = shape.iter().product();
        if want != self.data.len() {
            return Err(Error::Runtime(format!(
                "reshape {:?} -> {shape:?}: element count mismatch",
                self.shape
            )));
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    /// Elementwise in-place AXPY: `self += alpha * other` (shapes must match).
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) -> Result<()> {
        if self.shape != other.shape {
            return Err(Error::Runtime(format!(
                "axpy shape mismatch {:?} vs {:?}",
                self.shape, other.shape
            )));
        }
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
        Ok(())
    }

    /// In-place scale.
    pub fn scale(&mut self, alpha: f32) {
        for a in &mut self.data {
            *a *= alpha;
        }
    }

    /// L2 norm of the flattened tensor.
    pub fn l2(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_indexing() {
        let mut t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.len(), 24);
        t.set(&[1, 2, 3], 7.0);
        assert_eq!(t.get(&[1, 2, 3]), 7.0);
        assert_eq!(t.offset(&[1, 2, 3]), 23);
        assert_eq!(t.offset(&[0, 0, 0]), 0);
    }

    #[test]
    fn from_vec_validates() {
        assert!(Tensor::from_vec(&[2, 2], vec![0.0; 3]).is_err());
        assert!(Tensor::from_vec(&[2, 2], vec![0.0; 4]).is_ok());
    }

    #[test]
    fn reshape_checks_count() {
        let t = Tensor::zeros(&[4, 3]);
        assert!(t.clone().reshape(&[3, 4]).is_ok());
        assert!(t.reshape(&[5, 2]).is_err());
    }

    #[test]
    fn axpy_and_norm() {
        let mut a = Tensor::full(&[3], 1.0);
        let b = Tensor::full(&[3], 2.0);
        a.axpy(0.5, &b).unwrap();
        assert_eq!(a.data(), &[2.0, 2.0, 2.0]);
        assert!((a.l2() - (12.0f32).sqrt()).abs() < 1e-6);
        let bad = Tensor::zeros(&[4]);
        assert!(a.axpy(1.0, &bad).is_err());
    }
}
