//! Top-k selection over scored items — the core of the recall@K evaluator
//! (the paper's metric is recall@20 over scored relation triplets).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Min-heap entry: ordered by score ascending so the heap root is the
/// *worst* of the current top-k and can be evicted cheaply.
struct Entry {
    score: f32,
    index: usize,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.score == other.score && self.index == other.index
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse on score => BinaryHeap (max-heap) behaves as a min-heap:
        // the root is the lowest score. Among equal scores the root is the
        // *largest* index, so ties evict high indices first (deterministic
        // "prefer lower index" semantics).
        other
            .score
            .partial_cmp(&self.score)
            .unwrap_or(Ordering::Equal)
            .then_with(|| self.index.cmp(&other.index))
    }
}

/// Indices of the `k` largest scores, ordered by descending score
/// (ties: ascending index). `O(n log k)`, exact and deterministic.
pub fn top_k_indices(scores: &[f32], k: usize) -> Vec<usize> {
    if k == 0 || scores.is_empty() {
        return Vec::new();
    }
    let mut heap: BinaryHeap<Entry> = BinaryHeap::with_capacity(k + 1);
    for (index, &score) in scores.iter().enumerate() {
        debug_assert!(!score.is_nan(), "NaN score at {index}");
        if heap.len() < k {
            heap.push(Entry { score, index });
        } else if let Some(worst) = heap.peek() {
            if score > worst.score
                || (score == worst.score && index < worst.index)
            {
                heap.pop();
                heap.push(Entry { score, index });
            }
        }
    }
    let mut out: Vec<Entry> = heap.into_vec();
    out.sort_by(|a, b| {
        b.score
            .partial_cmp(&a.score)
            .unwrap_or(Ordering::Equal)
            .then_with(|| a.index.cmp(&b.index))
    });
    out.into_iter().map(|e| e.index).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn picks_largest() {
        let s = [0.1, 0.9, 0.5, 0.7];
        assert_eq!(top_k_indices(&s, 2), vec![1, 3]);
    }

    #[test]
    fn k_larger_than_n_returns_all_sorted() {
        let s = [0.3, 0.1, 0.2];
        assert_eq!(top_k_indices(&s, 10), vec![0, 2, 1]);
    }

    #[test]
    fn k_zero_and_empty() {
        assert!(top_k_indices(&[1.0], 0).is_empty());
        assert!(top_k_indices(&[], 3).is_empty());
    }

    #[test]
    fn ties_break_on_lower_index() {
        let s = [0.5, 0.5, 0.5, 0.5];
        assert_eq!(top_k_indices(&s, 2), vec![0, 1]);
    }

    #[test]
    fn matches_full_sort_randomized() {
        let mut rng = Rng::new(99);
        for case in 0..200 {
            let n = rng.range(1, 60);
            let k = rng.range(1, 25);
            let scores: Vec<f32> =
                (0..n).map(|_| (rng.below(20) as f32) / 10.0).collect();
            let got = top_k_indices(&scores, k);
            let mut idx: Vec<usize> = (0..n).collect();
            idx.sort_by(|&a, &b| {
                scores[b]
                    .partial_cmp(&scores[a])
                    .unwrap()
                    .then_with(|| a.cmp(&b))
            });
            idx.truncate(k.min(n));
            assert_eq!(got, idx, "case {case}: scores={scores:?} k={k}");
        }
    }
}
