//! CLI smoke tests: every subcommand's happy path and its flag errors,
//! exercised through the public `cli::run` dispatcher (no subprocess).

use bload::cli::run;

fn argv(s: &[&str]) -> Vec<String> {
    s.iter().map(|x| x.to_string()).collect()
}

/// Serializes tests whose commands call `telemetry::reset()` (`top`,
/// `assault`) — a reset landing mid-run in a parallel test would zero
/// the counters that test later asserts on.
static TELEMETRY_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn telemetry_lock() -> std::sync::MutexGuard<'static, ()> {
    TELEMETRY_LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

#[test]
fn no_command_prints_help_and_exits_2() {
    assert_eq!(run(&argv(&[])).unwrap(), 2);
    assert_eq!(run(&argv(&["definitely-not-a-command"])).unwrap(), 2);
}

#[test]
fn help_flag_short_circuits() {
    assert_eq!(run(&argv(&["pack", "--help"])).unwrap(), 0);
}

#[test]
fn inspect_small_scale() {
    assert_eq!(
        run(&argv(&["inspect", "--scale", "0.01", "--seed", "3"])).unwrap(),
        0
    );
}

#[test]
fn pack_all_strategies_small_scale() {
    for s in ["bload", "naive", "sampling", "mix_pad", "ffd", "bucket"] {
        assert_eq!(
            run(&argv(&["pack", "--strategy", s, "--scale", "0.02"]))
                .unwrap(),
            0,
            "{s}"
        );
    }
}

#[test]
fn strategies_lists_registry() {
    assert_eq!(run(&argv(&["strategies"])).unwrap(), 0);
    assert!(run(&argv(&["strategies", "--bogus", "1"])).is_err());
}

#[test]
fn pack_rejects_unknown_strategy_and_flags() {
    assert!(run(&argv(&["pack", "--strategy", "bogus"])).is_err());
    assert!(run(&argv(&["pack", "--bogus-flag", "1"])).is_err());
}

#[test]
fn pack_viz_all_figures() {
    for s in ["none", "bload", "naive", "sampling", "mix_pad", "ffd",
              "bucket"] {
        assert_eq!(
            run(&argv(&["pack-viz", "--strategy", s])).unwrap(),
            0,
            "{s}"
        );
    }
}

#[test]
fn gen_data_writes_store() {
    let out = std::env::temp_dir().join(format!(
        "bload_cli_smoke_{}.blds",
        std::process::id()
    ));
    let out_s = out.to_str().unwrap().to_string();
    assert_eq!(
        run(&argv(&["gen-data", "--out", &out_s, "--scale", "0.003"]))
            .unwrap(),
        0
    );
    let (_seed, videos) =
        bload::dataset::store::read_store(&out).unwrap();
    assert!(!videos.is_empty());
    std::fs::remove_file(&out).ok();
}

#[test]
fn replay_round_trips_gen_data_with_verify() {
    let out = std::env::temp_dir().join(format!(
        "bload_cli_replay_{}.blds",
        std::process::id()
    ));
    let out_s = out.to_str().unwrap().to_string();
    assert_eq!(
        run(&argv(&[
            "gen-data", "--out", &out_s, "--scale", "0.005", "--seed", "5",
        ]))
        .unwrap(),
        0
    );
    // Store-backed epoch must be byte-identical to the in-memory run.
    assert_eq!(
        run(&argv(&[
            "replay", "--store", &out_s, "--scale", "0.005", "--verify",
        ]))
        .unwrap(),
        0
    );
    // A wrong generation scale changes the video set: the loaders
    // diverge and verify must fail loudly instead of passing silently.
    assert!(run(&argv(&[
        "replay", "--store", &out_s, "--scale", "0.002", "--verify",
    ]))
    .is_err());
    std::fs::remove_file(&out).ok();
    assert!(run(&argv(&["replay", "--store", &out_s])).is_err(),
            "missing store file must error");
}

#[test]
fn pack_shards_writes_set_inspects_and_replays_with_verify() {
    let dir = std::env::temp_dir().join(format!(
        "bload_cli_shards_{}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&dir).ok();
    let dir_s = dir.to_str().unwrap().to_string();
    assert_eq!(
        run(&argv(&[
            "pack", "--strategy", "bload", "--scale", "0.01", "--seed",
            "5", "--shards", "3", "--out", &dir_s,
        ]))
        .unwrap(),
        0
    );
    assert!(dir.join("shards.json").exists());
    assert!(dir.join("shard-002.blds").exists());
    // Inspect verifies every shard CRC.
    assert_eq!(run(&argv(&["shards", "--dir", &dir_s])).unwrap(), 0);
    // Shard-backed replay must be byte-identical to the in-memory run.
    assert_eq!(
        run(&argv(&[
            "replay", "--store", &dir_s, "--scale", "0.01", "--seed",
            "5", "--verify",
        ]))
        .unwrap(),
        0
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn serve_replay_remote_round_trips_with_verify() {
    let pid = std::process::id();
    let dir = std::env::temp_dir().join(format!("bload_cli_serve_{pid}"));
    std::fs::remove_dir_all(&dir).ok();
    let dir_s = dir.to_str().unwrap().to_string();
    assert_eq!(
        run(&argv(&[
            "pack", "--strategy", "bload", "--scale", "0.01", "--seed",
            "5", "--shards", "2", "--out", &dir_s,
        ]))
        .unwrap(),
        0
    );

    // The daemon blocks in `server.wait()`, so it runs on its own
    // thread; `--addr-file` publishes the ephemeral bound address once
    // the listener is up (no bind race, no fixed port).
    let addr_file =
        std::env::temp_dir().join(format!("bload_cli_serve_{pid}.addr"));
    std::fs::remove_file(&addr_file).ok();
    let addr_file_s = addr_file.to_str().unwrap().to_string();
    let serve_dir = dir_s.clone();
    let serve_addr_file = addr_file_s.clone();
    let daemon = std::thread::spawn(move || {
        run(&argv(&[
            "serve", "--dir", &serve_dir, "--addr", "127.0.0.1:0",
            "--addr-file", &serve_addr_file,
        ]))
    });
    let deadline = std::time::Instant::now()
        + std::time::Duration::from_secs(10);
    let addr = loop {
        match std::fs::read_to_string(&addr_file) {
            Ok(a) if !a.trim().is_empty() => break a.trim().to_string(),
            _ if std::time::Instant::now() > deadline => {
                panic!("serve daemon never published its address")
            }
            _ => std::thread::sleep(std::time::Duration::from_millis(10)),
        }
    };

    // Remote replay must be byte-identical to the in-memory run — the
    // same gate the local shard replay passes.
    assert_eq!(
        run(&argv(&[
            "replay", "--remote", &addr, "--scale", "0.01", "--seed",
            "5", "--verify",
        ]))
        .unwrap(),
        0
    );

    // SHUTDOWN drains the daemon; the serve command exits 0.
    bload::net::RemoteClient::connect(
        &addr, &bload::net::ClientConfig::default())
    .unwrap()
    .shutdown_server()
    .unwrap();
    assert_eq!(daemon.join().unwrap().unwrap(), 0);
    std::fs::remove_file(&addr_file).ok();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn fleet_replay_round_trips_two_daemons_with_verify() {
    let pid = std::process::id();
    let dir = std::env::temp_dir().join(format!("bload_cli_fleet_{pid}"));
    std::fs::remove_dir_all(&dir).ok();
    let dir_s = dir.to_str().unwrap().to_string();
    assert_eq!(
        run(&argv(&[
            "pack", "--strategy", "bload", "--scale", "0.01", "--seed",
            "5", "--shards", "2", "--out", &dir_s,
        ]))
        .unwrap(),
        0
    );

    // Two daemons serving the same shard set, each publishing its
    // ephemeral bound address through --addr-file.
    let mut daemons = Vec::new();
    let mut addrs = Vec::new();
    let mut addr_files = Vec::new();
    for i in 0..2 {
        let addr_file = std::env::temp_dir()
            .join(format!("bload_cli_fleet_{pid}_{i}.addr"));
        std::fs::remove_file(&addr_file).ok();
        let addr_file_s = addr_file.to_str().unwrap().to_string();
        let serve_dir = dir_s.clone();
        let serve_addr_file = addr_file_s.clone();
        daemons.push(std::thread::spawn(move || {
            run(&argv(&[
                "serve", "--dir", &serve_dir, "--addr", "127.0.0.1:0",
                "--addr-file", &serve_addr_file,
            ]))
        }));
        let deadline = std::time::Instant::now()
            + std::time::Duration::from_secs(10);
        let addr = loop {
            match std::fs::read_to_string(&addr_file) {
                Ok(a) if !a.trim().is_empty() => break a.trim().to_string(),
                _ if std::time::Instant::now() > deadline => {
                    panic!("daemon {i} never published its address")
                }
                _ => std::thread::sleep(
                    std::time::Duration::from_millis(10)),
            }
        };
        addrs.push(addr);
        addr_files.push(addr_file);
    }
    let hosts = addrs.join(",");

    // The striped fleet epoch must be byte-identical to the in-memory
    // run — the same gate the single-daemon remote replay passes.
    assert_eq!(
        run(&argv(&[
            "replay", "--fleet", &hosts, "--scale", "0.01", "--seed",
            "5", "--verify",
        ]))
        .unwrap(),
        0
    );

    // `top --fleet --snapshot` polls both daemons' STATS in one frame.
    let snap_out = std::env::temp_dir()
        .join(format!("bload_cli_fleet_{pid}_top.json"));
    let snap_out_s = snap_out.to_str().unwrap().to_string();
    assert_eq!(
        run(&argv(&[
            "top", "--fleet", &hosts, "--snapshot", "--out", &snap_out_s,
        ]))
        .unwrap(),
        0
    );
    let snap = std::fs::read_to_string(&snap_out).unwrap();
    assert!(snap.contains("fleet.requests"), "{snap}");

    for addr in &addrs {
        bload::net::RemoteClient::connect(
            addr, &bload::net::ClientConfig::default())
        .unwrap()
        .shutdown_server()
        .unwrap();
    }
    for d in daemons {
        assert_eq!(d.join().unwrap().unwrap(), 0);
    }
    for f in addr_files {
        std::fs::remove_file(&f).ok();
    }
    std::fs::remove_file(&snap_out).ok();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn replay_and_top_reject_conflicting_fleet_flags() {
    assert!(
        run(&argv(&[
            "replay", "--fleet", "a:1", "--remote", "b:2",
        ]))
        .is_err(),
        "--fleet and --remote are mutually exclusive"
    );
    assert!(
        run(&argv(&["top", "--fleet", "a:1", "--remote", "b:2"]))
            .is_err(),
        "--fleet and --remote are mutually exclusive"
    );
    assert!(run(&argv(&["top", "--fleet", " , "])).is_err(),
            "--fleet needs at least one host");
    assert!(run(&argv(&["top", "--polls", "2"])).is_err(),
            "--polls needs --remote or --fleet");
}

#[test]
fn serve_rejects_missing_dir_and_bad_flags() {
    assert!(run(&argv(&["serve"])).is_err(), "--dir is required");
    assert!(run(&argv(&["serve", "--dir", "/nope/missing"])).is_err());
    assert!(run(&argv(&["serve", "--bogus", "1"])).is_err());
}

#[test]
fn pack_rejects_out_without_shards() {
    assert!(run(&argv(&["pack", "--scale", "0.01", "--out", "/tmp/x"]))
        .is_err());
}

#[test]
fn shards_bench_scenario_completes() {
    assert_eq!(
        run(&argv(&[
            "shards", "--bench", "--scale", "0.01", "--shards", "2",
            "--readers", "2",
        ]))
        .unwrap(),
        0
    );
}

#[test]
fn shards_requires_dir_or_bench_but_not_both() {
    assert!(run(&argv(&["shards"])).is_err());
    assert!(run(&argv(&["shards", "--dir", "/nope/missing"])).is_err());
    assert!(run(&argv(&["shards", "--bogus", "1"])).is_err());
    assert!(run(&argv(&["shards", "--dir", "/x", "--bench"])).is_err());
}

#[test]
fn deadlock_demo_completes() {
    assert_eq!(
        run(&argv(&[
            "deadlock-demo", "--ranks", "2", "--batch", "2",
            "--timeout-ms", "120",
        ]))
        .unwrap(),
        0
    );
}

#[test]
fn ingest_streaming_mode_completes() {
    assert_eq!(
        run(&argv(&[
            "ingest", "--scale", "0.02", "--ranks", "2", "--window", "32",
            "--producers", "2",
        ]))
        .unwrap(),
        0
    );
}

#[test]
fn ingest_rejects_bad_flags() {
    assert!(run(&argv(&["ingest", "--ranks", "0"])).is_err());
    assert!(run(&argv(&["ingest", "--bogus", "1"])).is_err());
    assert!(run(&argv(&["ingest", "--window", "abc"])).is_err());
}

#[test]
fn table1_pipeline_level() {
    // Pipeline accounting only (no --full): packs the full AG-Synth split
    // with every registered strategy and prints the paper-side table.
    assert_eq!(run(&argv(&["table1"])).unwrap(), 0);
}

#[test]
fn bench_list_and_flag_errors() {
    assert_eq!(run(&argv(&["bench", "--list"])).unwrap(), 0);
    // Unknown suites and unknown flags are hard errors.
    assert!(run(&argv(&["bench", "--suite", "nope"])).is_err());
    assert!(run(&argv(&["bench", "--bogus", "1"])).is_err());
    // --report is meaningless without the baseline to diff against.
    assert!(run(&argv(&["bench", "--report", "/tmp/x.json"])).is_err());
    // File-vs-file mode runs nothing: run-only flags are rejected, not
    // silently ignored.
    assert!(run(&argv(&[
        "bench", "--smoke", "--compare", "/tmp/a.json", "--report",
        "/tmp/b.json",
    ]))
    .is_err());
}

#[test]
fn bench_smoke_suite_writes_valid_json_report() {
    let out = std::env::temp_dir().join(format!(
        "bload_cli_bench_{}.json",
        std::process::id()
    ));
    let out_s = out.to_str().unwrap().to_string();
    assert_eq!(
        run(&argv(&[
            "bench", "--smoke", "--suite", "packing", "--json", &out_s,
        ]))
        .unwrap(),
        0
    );
    let report = bload::benchkit::Report::load(&out).unwrap();
    assert!(report.meta.smoke);
    assert_eq!(report.meta.label, "smoke");
    assert!(
        !report.entries.is_empty(),
        "packing suite produced no results"
    );
    assert!(report.entries.iter().all(|e| e.suite == "packing"));
    assert!(report
        .entries
        .iter()
        .all(|e| e.result.mean_s >= 0.0 && e.result.iters > 0));
    // Comparing a report against itself through the CLI exits 0.
    assert_eq!(
        run(&argv(&[
            "bench", "--compare", &out_s, "--report", &out_s,
        ]))
        .unwrap(),
        0
    );
    std::fs::remove_file(&out).ok();
}

#[test]
fn bench_compare_gates_on_injected_regression() {
    use bload::benchkit::{BenchResult, Bencher, Report, RunMeta};
    let mut base = Report::new(RunMeta::capture(
        "smoke",
        &Bencher::smoke(),
        true,
    ));
    base.push_suite(
        "s",
        vec![BenchResult {
            name: "s/hot_path".into(),
            iters: 3,
            mean_s: 1.0,
            p50_s: 1.0,
            p95_s: 1.2,
            min_s: 0.9,
            throughput: None,
        }],
    );
    let mut slow = base.clone();
    slow.entries[0].result.mean_s = 2.0;
    slow.entries[0].result.p50_s = 2.0;
    let dir = std::env::temp_dir();
    let pid = std::process::id();
    let base_p = dir.join(format!("bload_cli_bench_base_{pid}.json"));
    let slow_p = dir.join(format!("bload_cli_bench_slow_{pid}.json"));
    base.save(&base_p).unwrap();
    slow.save(&slow_p).unwrap();
    let base_s = base_p.to_str().unwrap().to_string();
    let slow_s = slow_p.to_str().unwrap().to_string();
    // Identical: exit 0. Injected 2x regression: exit 1.
    assert_eq!(
        run(&argv(&["bench", "--compare", &base_s, "--report", &base_s]))
            .unwrap(),
        0
    );
    assert_eq!(
        run(&argv(&["bench", "--compare", &base_s, "--report", &slow_s]))
            .unwrap(),
        1
    );
    // The regression is noise-gated: a custom threshold admits it.
    assert_eq!(
        run(&argv(&[
            "bench", "--compare", &base_s, "--report", &slow_s,
            "--threshold", "150", "--p50-threshold", "150",
        ]))
        .unwrap(),
        0
    );
    std::fs::remove_file(&base_p).ok();
    std::fs::remove_file(&slow_p).ok();
}

#[test]
fn top_snapshot_writes_format1_json_with_live_metrics() {
    let _g = telemetry_lock();
    let out = std::env::temp_dir().join(format!(
        "bload_cli_top_{}.json",
        std::process::id()
    ));
    let out_s = out.to_str().unwrap().to_string();
    assert_eq!(
        run(&argv(&[
            "top", "--snapshot", "--out", &out_s, "--scale", "0.01",
            "--seed", "3",
        ]))
        .unwrap(),
        0
    );
    let text = std::fs::read_to_string(&out).unwrap();
    let v = bload::jsonio::parse(&text).unwrap();
    assert_eq!(v.get("format").and_then(|f| f.as_usize()), Some(1));
    let snap = bload::telemetry::Snapshot::from_value(&v).unwrap();
    // One live metric per instrumented subsystem — the documented
    // snapshot keys (see telemetry::names and the README table).
    assert!(snap.counter("ingest.arrivals") > 0, "ingest queue idle");
    assert!(snap.counter("ingest.blocks") > 0, "no blocks packed");
    assert!(
        snap.counter("loader.cache_hits")
            + snap.counter("loader.cache_misses")
            > 0,
        "loader cache untouched"
    );
    assert!(snap.counter("shardstore.reads") > 0, "no shard reads");
    assert!(snap.counter("net.requests") > 0, "no served requests");
    assert!(
        snap.histograms.contains_key("train.rank0.step_s"),
        "no per-rank step timings"
    );
    std::fs::remove_file(&out).ok();
}

#[test]
fn top_list_and_flag_errors() {
    let _g = telemetry_lock();
    assert_eq!(run(&argv(&["top", "--list"])).unwrap(), 0);
    assert!(run(&argv(&["top", "--bogus", "1"])).is_err());
    // --out without --snapshot is a hard error, not silently ignored.
    assert!(run(&argv(&["top", "--out", "/tmp/x.json"])).is_err());
    assert!(run(&argv(&["top", "--snapshot", "--ranks", "0"])).is_err());
    assert!(run(&argv(&["top", "--scale", "abc"])).is_err());
    // --polls only makes sense for the remote polling loop.
    assert!(run(&argv(&["top", "--polls", "2"])).is_err());
}

#[test]
fn assault_list_evaluators_and_flag_errors() {
    assert_eq!(run(&argv(&["assault", "--list-evaluators"])).unwrap(), 0);
    assert!(run(&argv(&["assault"])).is_err(), "--config is required");
    assert!(run(&argv(&["assault", "--bogus", "1"])).is_err());
    assert!(run(&argv(&["assault", "--config", "/nope/missing.toml"]))
        .is_err());
}

/// The full scenario path: pack a shard set, serve it on a loopback
/// port, run a three-testcase scenario file against it (serve
/// byte-identity, serve latency-SLO, shards padding-budget), then
/// flip one SLO to an impossible bound and watch the exit code go
/// nonzero. Also exercises `top --remote` against the same daemon.
#[test]
fn assault_scenario_round_trips_against_loopback_serve() {
    let _g = telemetry_lock();
    let pid = std::process::id();
    let dir = std::env::temp_dir().join(format!("bload_cli_assault_{pid}"));
    std::fs::remove_dir_all(&dir).ok();
    let dir_s = dir.to_str().unwrap().to_string();
    assert_eq!(
        run(&argv(&[
            "pack", "--strategy", "bload", "--scale", "0.004", "--shards",
            "2", "--out", &dir_s,
        ]))
        .unwrap(),
        0
    );

    let addr_file =
        std::env::temp_dir().join(format!("bload_cli_assault_{pid}.addr"));
    std::fs::remove_file(&addr_file).ok();
    let addr_file_s = addr_file.to_str().unwrap().to_string();
    let serve_dir = dir_s.clone();
    let serve_addr_file = addr_file_s.clone();
    let daemon = std::thread::spawn(move || {
        run(&argv(&[
            "serve", "--dir", &serve_dir, "--addr", "127.0.0.1:0",
            "--addr-file", &serve_addr_file,
        ]))
    });
    let deadline = std::time::Instant::now()
        + std::time::Duration::from_secs(10);
    let addr = loop {
        match std::fs::read_to_string(&addr_file) {
            Ok(a) if !a.trim().is_empty() => break a.trim().to_string(),
            _ if std::time::Instant::now() > deadline => {
                panic!("serve daemon never published its address")
            }
            _ => std::thread::sleep(std::time::Duration::from_millis(10)),
        }
    };

    // `bload top --remote --snapshot`: one STATS poll, format-1 JSON.
    let top_out = std::env::temp_dir()
        .join(format!("bload_cli_assault_top_{pid}.json"));
    let top_out_s = top_out.to_str().unwrap().to_string();
    assert_eq!(
        run(&argv(&[
            "top", "--remote", &addr, "--snapshot", "--out", &top_out_s,
        ]))
        .unwrap(),
        0
    );
    let v = bload::jsonio::parse(
        &std::fs::read_to_string(&top_out).unwrap()).unwrap();
    let snap = bload::telemetry::Snapshot::from_value(&v).unwrap();
    assert!(snap.counter("net.connections") >= 1,
            "the STATS poll itself was accepted");
    // A bounded live polling loop also completes.
    assert_eq!(
        run(&argv(&[
            "top", "--remote", &addr, "--polls", "2", "--refresh-ms",
            "30",
        ]))
        .unwrap(),
        0
    );

    // No [dataset] section: byte-identity only needs the generator
    // *family* (geometry + seed from the manifest), and the defaults
    // match what `pack` served.
    let scenario = |slo: &str| {
        format!(
            "[assault]\n\
             name = cli-smoke\n\
             destinations = [\"{addr}\", \"{dir_s}\"]\n\
             [assault.setting]\n\
             repeat = 2\n\
             concurrency = 4\n\
             timeout = 10s\n\
             [[assault.testcase]]\n\
             name = replay-identity\n\
             destination = @0\n\
             evaluator = byte-identity\n\
             [[assault.testcase]]\n\
             name = tail-latency\n\
             destination = @0\n\
             evaluator = latency-slo\n\
             slo = {slo}\n\
             [[assault.testcase]]\n\
             name = padding\n\
             destination = @1\n\
             evaluator = padding-budget\n"
        )
    };

    let cfg_path = std::env::temp_dir()
        .join(format!("bload_cli_assault_{pid}.toml"));
    let cfg_s = cfg_path.to_str().unwrap().to_string();
    let json_path = std::env::temp_dir()
        .join(format!("bload_cli_assault_{pid}.json"));
    let json_s = json_path.to_str().unwrap().to_string();

    // Generous SLO: every evaluator passes, exit 0, report saved.
    std::fs::write(&cfg_path, scenario("60s")).unwrap();
    assert_eq!(
        run(&argv(&[
            "assault", "--config", &cfg_s, "--json", &json_s,
        ]))
        .unwrap(),
        0
    );
    let report = bload::benchkit::Report::load(&json_path).unwrap();
    assert_eq!(report.entries.len(), 3);
    assert!(report.entries.iter().all(|e| e.suite == "assault"));
    assert!(report
        .get("assault/replay-identity/request")
        .is_some());

    // A 1ns SLO on a real TCP round-trip cannot pass: exit code 1
    // (a failed verdict, not a hard error).
    std::fs::write(&cfg_path, scenario("0.000001ms")).unwrap();
    assert_eq!(
        run(&argv(&["assault", "--config", &cfg_s])).unwrap(),
        1
    );

    bload::net::RemoteClient::connect(
        &addr, &bload::net::ClientConfig::default())
    .unwrap()
    .shutdown_server()
    .unwrap();
    assert_eq!(daemon.join().unwrap().unwrap(), 0);
    std::fs::remove_file(&addr_file).ok();
    std::fs::remove_file(&cfg_path).ok();
    std::fs::remove_file(&json_path).ok();
    std::fs::remove_file(&top_out).ok();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn train_rejects_missing_config() {
    assert!(run(&argv(&["train", "--config", "/nope/missing.toml"]))
        .is_err());
}

#[test]
fn train_smoke_config_runs_if_artifacts_built() {
    let manifest = std::path::Path::new("artifacts/manifest.json");
    let config = std::path::Path::new("configs/smoke.toml");
    if !manifest.exists() || !config.exists() {
        eprintln!("skipping: artifacts/config not present");
        return;
    }
    assert_eq!(
        run(&argv(&["train", "--config", "configs/smoke.toml"])).unwrap(),
        0
    );
}
