//! Loader determinism properties: for a fixed `(seed, epoch)` the
//! builder pipeline must deliver the exact same `DeviceBatch` sequence
//! regardless of `workers` and `depth`, in both planned and stream
//! modes — worker scheduling may reorder *materialization*, never
//! *delivery*. Plus the stream-mode worker-death contract: a worker dying
//! after claiming a step surfaces as a truncated-epoch error, not a
//! silently shorter epoch.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use bload::config::ExperimentConfig;
use bload::dataset::synthetic::generate;
use bload::dataset::Split;
use bload::loader::{BlockSource, DataLoaderBuilder, DeviceBatch, WorkUnit};
use bload::packing::{by_name, pack, Block, PackedDataset};

fn setup(seed: u64) -> (Arc<Split>, Arc<PackedDataset>) {
    let cfg = ExperimentConfig::default_config();
    let ds = generate(&cfg.dataset.scaled(0.01), seed);
    let packed = Arc::new(
        pack(by_name("bload").unwrap(), &ds.train, &cfg.packing, seed)
            .unwrap(),
    );
    (Arc::new(ds.train), packed)
}

/// Everything observable about one batch, for exact sequence comparison.
fn fingerprint(b: &DeviceBatch) -> (Vec<usize>, Vec<u32>, Vec<u32>,
                                    Vec<u32>, Vec<u32>, usize, usize) {
    // f32 payloads compare bitwise via their bit patterns.
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect();
    (
        b.block_ids.clone(),
        bits(&b.feats),
        bits(&b.labels),
        bits(&b.frame_mask),
        bits(&b.seg_ids),
        b.real_frames,
        b.slots,
    )
}

#[test]
fn planned_sequence_invariant_under_workers_and_depth() {
    let (split, packed) = setup(5);
    let runs: Vec<Vec<_>> = [(1usize, 1usize), (1, 4), (2, 2), (4, 1),
                             (4, 8), (8, 3)]
        .iter()
        .map(|&(workers, depth)| {
            let mut loader = DataLoaderBuilder::new()
                .batch(2)
                .workers(workers)
                .depth(depth)
                .seed(21)
                .shard(2, 1)
                .planned(Arc::clone(&split), Arc::clone(&packed), 3)
                .unwrap();
            let mut out = Vec::new();
            while let Some(b) = loader.next() {
                out.push(fingerprint(&b.unwrap()));
            }
            out
        })
        .collect();
    assert!(runs[0].len() >= 2, "need a few steps, got {}", runs[0].len());
    for (i, r) in runs.iter().enumerate().skip(1) {
        assert_eq!(
            *r, runs[0],
            "planned run {i} diverged from the single-worker baseline"
        );
    }
}

#[test]
fn stream_sequence_invariant_under_workers_and_depth() {
    let (split, packed) = setup(6);
    let runs: Vec<Vec<_>> = [(1usize, 1usize), (2, 3), (4, 2), (8, 8)]
        .iter()
        .map(|&(workers, depth)| {
            let (tx, rx) = std::sync::mpsc::sync_channel(4);
            let feeder = {
                let packed = Arc::clone(&packed);
                std::thread::spawn(move || {
                    for b in &packed.blocks {
                        if tx.send(b.clone()).is_err() {
                            return;
                        }
                    }
                })
            };
            let mut loader = DataLoaderBuilder::new()
                .batch(3)
                .workers(workers)
                .depth(depth)
                .stream(Arc::clone(&split), rx, packed.block_len)
                .unwrap();
            let mut out = Vec::new();
            while let Some(b) = loader.next() {
                out.push(fingerprint(&b.unwrap()));
            }
            feeder.join().unwrap();
            out
        })
        .collect();
    assert!(runs[0].len() >= 2, "need a few steps, got {}", runs[0].len());
    for (i, r) in runs.iter().enumerate().skip(1) {
        assert_eq!(
            *r, runs[0],
            "stream run {i} diverged from the single-worker baseline"
        );
    }
}

/// Stream-shaped source whose second unit kills the claiming worker
/// (panics after bumping the claim counter) — the "worker died mid-step"
/// scenario the loader must turn into an error.
struct DyingSource {
    split: Arc<Split>,
    block: Block,
    block_len: usize,
    claimed: AtomicUsize,
}

impl BlockSource for DyingSource {
    fn split(&self) -> &Arc<Split> {
        &self.split
    }

    fn block_len(&self) -> usize {
        self.block_len
    }

    fn next_unit(&self) -> Option<WorkUnit> {
        let step = self.claimed.fetch_add(1, Ordering::SeqCst);
        if step >= 1 {
            // Claimed but never delivered: the worker thread dies here.
            panic!("simulated loader-worker death");
        }
        Some(WorkUnit {
            step,
            blocks: vec![(step, self.block.clone())],
        })
    }

    fn claimed(&self) -> usize {
        self.claimed.load(Ordering::SeqCst)
    }

    fn steps(&self) -> Option<usize> {
        None // open-ended, like a stream
    }
}

#[test]
fn stream_worker_death_truncates_epoch_with_error() {
    let (split, packed) = setup(7);
    let source = Arc::new(DyingSource {
        split,
        block: packed.blocks[0].clone(),
        block_len: packed.block_len,
        claimed: AtomicUsize::new(0),
    });
    // One worker: it delivers step 0, then dies claiming step 1.
    let mut loader = DataLoaderBuilder::new()
        .workers(1)
        .depth(2)
        .source(source)
        .unwrap();
    let first = loader.next().expect("step 0 delivered");
    assert_eq!(first.unwrap().block_ids, vec![0]);
    let err = loader
        .next()
        .expect("death must surface as an error, not a clean end")
        .unwrap_err()
        .to_string();
    assert!(err.contains("died"), "{err}");
    assert!(loader.next().is_none(), "loader is done after the error");
}
