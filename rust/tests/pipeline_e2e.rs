//! Integration: the full data pipeline (generate → pack → shard →
//! prefetch → device batches) without the PJRT runtime, plus randomized
//! cross-strategy properties. These tests exercise module *composition*;
//! per-module behaviour lives in unit tests.

use std::collections::HashMap;
use std::sync::Arc;

use bload::config::ExperimentConfig;
use bload::dataset::synthetic::generate;
use bload::loader::{DataLoaderBuilder, EpochPlan};
use bload::packing::{by_name, pack, pack_with_block_len, registry,
                     validate::validate, Packer};
use bload::util::Rng;

#[test]
fn bload_pipeline_conserves_every_frame() {
    let cfg = ExperimentConfig::default_config();
    let dcfg = cfg.dataset.scaled(0.02);
    let ds = generate(&dcfg, 7);
    let packed =
        Arc::new(pack(by_name("bload").unwrap(), &ds.train, &cfg.packing, 7)
            .unwrap());
    let split = Arc::new(ds.train);

    // Stream one epoch on one rank; count per-video frames delivered.
    let plan = EpochPlan::new(&packed, 1, 0, 2, true, 7, 0);
    let mut loader = DataLoaderBuilder::new()
        .batch(2)
        .workers(3)
        .depth(4)
        .seed(7)
        .planned(Arc::clone(&split), Arc::clone(&packed), 0)
        .unwrap();
    let mut frames_delivered = 0usize;
    while let Some(b) = loader.next() {
        let b = b.unwrap();
        frames_delivered += b.real_frames;
        // Mask and seg ids agree on occupancy for bload.
        for i in 0..b.frame_mask.len() {
            assert_eq!(b.frame_mask[i] > 0.5, b.seg_ids[i] >= 0.0);
        }
    }
    loader.shutdown();
    // Equal-shard epoch may drop a remainder batch but nothing else.
    let expected: usize = plan
        .batches
        .iter()
        .flatten()
        .map(|&i| packed.blocks[i].used())
        .sum();
    assert_eq!(frames_delivered, expected);
}

#[test]
fn multi_rank_epoch_covers_disjoint_blocks_with_equal_steps() {
    let cfg = ExperimentConfig::default_config();
    let ds = generate(&cfg.dataset.scaled(0.02), 1);
    let packed =
        Arc::new(pack(by_name("bload").unwrap(), &ds.train, &cfg.packing, 1)
            .unwrap());
    let ranks = 8;
    let mut seen = std::collections::HashSet::new();
    let mut steps = Vec::new();
    for r in 0..ranks {
        let plan = EpochPlan::new(&packed, ranks, r, 2, true, 1, 0);
        steps.push(plan.steps());
        for b in plan.batches.iter().flatten() {
            assert!(seen.insert(*b), "block {b} on two ranks");
        }
    }
    assert!(steps.windows(2).all(|w| w[0] == w[1]), "{steps:?}");
}

#[test]
fn all_strategies_produce_loadable_batches() {
    let cfg = ExperimentConfig::default_config();
    let dcfg = bload::harness::scaled_dataset(120, 30, 0.6);
    let pcfg = bload::harness::scaled_packing();
    let ds = generate(&dcfg, 3);
    for &strategy in registry() {
        let packed = Arc::new(
            pack_with_block_len(strategy, &ds.train, &pcfg, pcfg.t_max, 3)
                .unwrap(),
        );
        validate(&packed, &ds.train, strategy.within_video_padding())
            .unwrap();
        let split = Arc::new(ds.train.clone());
        let mut loader = DataLoaderBuilder::new()
            .batch(2)
            .workers(2)
            .depth(2)
            .seed(3)
            .shard(2, 0)
            .planned(split, Arc::clone(&packed), 0)
            .unwrap();
        if loader.steps() == Some(0) {
            continue;
        }
        let b = loader.next().unwrap().unwrap();
        assert_eq!(b.block_len, pcfg.t_max);
        assert!(b.real_frames > 0, "{}", strategy.name());
        loader.shutdown();
    }
    let _ = cfg;
}

#[test]
fn randomized_registry_invariants_hold() {
    // Property sweep over the FULL strategy registry: for random
    // geometries and seeds, every registered strategy's output passes
    // `packing::validate` (no overlap, in-bounds) and its accounting adds
    // up — `kept + padding == total_slots` and
    // `kept + deleted == source frames`. A newly registered strategy is
    // covered here with zero edits.
    let mut rng = Rng::new(0xFEED);
    for case in 0..30 {
        let mut dcfg = bload::harness::scaled_dataset(
            rng.range(10, 120), 5, 0.4 + rng.f64() * 0.5);
        dcfg.min_len = rng.range(1, 4);
        dcfg.max_len = rng.range(dcfg.min_len + 4, 30);
        dcfg.mean_len =
            dcfg.min_len as f64 + (dcfg.max_len - dcfg.min_len) as f64 * 0.4;
        let ds = generate(&dcfg, rng.next_u64());
        let mut pcfg = bload::harness::scaled_packing();
        pcfg.t_max = dcfg.max_len.max(4);
        pcfg.t_block = rng.range(1, pcfg.t_max / 2 + 2);
        pcfg.t_mix = rng.range(1, pcfg.t_max + 1);
        for &strategy in registry() {
            let key = strategy.name();
            let packed = pack(strategy, &ds.train, &pcfg, rng.next_u64())
                .unwrap_or_else(|e| panic!("case {case} {key}: {e}"));
            validate(&packed, &ds.train, strategy.within_video_padding())
                .unwrap_or_else(|e| panic!("case {case} {key}: {e}"));
            let s = &packed.stats;
            let total = ds.train.total_frames();
            assert_eq!(s.frames_kept + s.padding, s.total_slots,
                       "case {case} {key}: kept + padding == slots");
            assert_eq!(s.frames_kept + s.frames_deleted, total,
                       "case {case} {key}: conservation");
            match key {
                // Whole-video packers never delete a frame.
                "bload" | "naive" | "ffd" | "bucket" => {
                    assert_eq!(s.frames_deleted, 0, "case {case} {key}");
                }
                // Chunking fills every emitted slot exactly.
                "sampling" => assert_eq!(s.padding, 0, "case {case}"),
                _ => {}
            }
        }
    }
}

#[test]
fn batches_are_bit_identical_across_runs() {
    // Determinism end to end: same seeds -> same bytes.
    let dcfg = bload::harness::scaled_dataset(60, 10, 0.6);
    let pcfg = bload::harness::scaled_packing();
    let collect = || -> Vec<f32> {
        let ds = generate(&dcfg, 11);
        let packed = Arc::new(
            pack_with_block_len(by_name("bload").unwrap(), &ds.train, &pcfg,
                                24, 11)
            .unwrap(),
        );
        let split = Arc::new(ds.train);
        let mut loader = DataLoaderBuilder::new()
            .batch(2)
            .workers(4)
            .depth(3)
            .seed(11)
            .shard(2, 1)
            .planned(split, packed, 4)
            .unwrap();
        let mut out = Vec::new();
        while let Some(b) = loader.next() {
            out.extend(b.unwrap().feats);
        }
        loader.shutdown();
        out
    };
    assert_eq!(collect(), collect());
}

#[test]
fn store_replay_is_byte_identical_to_in_memory_run() {
    // The StoreSource acceptance bar: a persisted shard replayed through
    // the builder pipeline delivers exactly the bytes of the equivalent
    // in-memory offline epoch — same shuffle, same sharding, same
    // content.
    use bload::dataset::store::StoreWriter;
    let cfg = ExperimentConfig::default_config();
    let dcfg = cfg.dataset.scaled(0.01);
    let gen_seed = 13u64;
    let ds = generate(&dcfg, gen_seed);

    let path = std::env::temp_dir().join(format!(
        "bload_replay_e2e_{}.blds",
        std::process::id()
    ));
    let mut w = StoreWriter::create(
        &path,
        gen_seed,
        (dcfg.objects as u32, dcfg.feat_dim as u32, dcfg.classes as u32),
        ds.train.videos.len() as u32,
    )
    .unwrap();
    for v in &ds.train.videos {
        w.append(&ds.train.spec.materialize(*v)).unwrap();
    }
    w.finish().unwrap();

    let builder = DataLoaderBuilder::new()
        .batch(2)
        .workers(3)
        .depth(2)
        .seed(13)
        .shard(2, 1);
    let mut from_store = builder
        .store(&path, &dcfg, by_name("bload").unwrap(), &cfg.packing, 2)
        .unwrap();
    let packed = Arc::new(
        pack(by_name("bload").unwrap(), &ds.train, &cfg.packing, 13)
            .unwrap(),
    );
    let mut in_memory = builder
        .planned(Arc::new(ds.train), packed, 2)
        .unwrap();

    assert_eq!(from_store.steps(), in_memory.steps());
    assert!(from_store.steps().unwrap_or(0) > 0, "epoch has steps");
    loop {
        match (from_store.next(), in_memory.next()) {
            (None, None) => break,
            (Some(a), Some(b)) => {
                let (a, b) = (a.unwrap(), b.unwrap());
                assert_eq!(a.block_ids, b.block_ids);
                assert_eq!(a.feats, b.feats);
                assert_eq!(a.labels, b.labels);
                assert_eq!(a.frame_mask, b.frame_mask);
                assert_eq!(a.seg_ids, b.seg_ids);
            }
            (a, b) => panic!(
                "step-count mismatch: store {:?} vs memory {:?}",
                a.map(|r| r.is_ok()),
                b.map(|r| r.is_ok())
            ),
        }
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn shard_replay_is_byte_identical_for_any_shard_count() {
    // The shardstore acceptance bar, extending
    // `store_replay_is_byte_identical_to_in_memory_run`: the same split
    // packed to 1, 2 and 5 shards replays — through the concurrent
    // ShardPool, actual stored bytes, multiple workers — the exact batch
    // sequence of the in-memory offline epoch, shuffle, sharding and
    // content included.
    use bload::dataset::shardstore::ShardSetWriter;
    let cfg = ExperimentConfig::default_config();
    let dcfg = cfg.dataset.scaled(0.01);
    let gen_seed = 13u64;
    let ds = generate(&dcfg, gen_seed);

    let builder = DataLoaderBuilder::new()
        .batch(2)
        .workers(3)
        .depth(2)
        .seed(13)
        .shard(2, 1);
    let packed = Arc::new(
        pack(by_name("bload").unwrap(), &ds.train, &cfg.packing, 13)
            .unwrap(),
    );
    let split = Arc::new(ds.train);
    let collect_memory = || {
        let mut loader = builder
            .planned(Arc::clone(&split), Arc::clone(&packed), 2)
            .unwrap();
        let mut out = Vec::new();
        while let Some(b) = loader.next() {
            out.push(b.unwrap());
        }
        out
    };
    let reference = collect_memory();
    assert!(!reference.is_empty(), "epoch has steps");

    for shards in [1usize, 2, 5] {
        let dir = std::env::temp_dir().join(format!(
            "bload_shard_replay_e2e_{}_{shards}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        ShardSetWriter::new(&dir, gen_seed, shards)
            .unwrap()
            .write(&split)
            .unwrap();
        let mut loader = builder
            .shards(&dir, &dcfg, by_name("bload").unwrap(),
                    &cfg.packing, 2)
            .unwrap();
        assert_eq!(loader.steps(), Some(reference.len()),
                   "{shards} shard(s)");
        for (step, want) in reference.iter().enumerate() {
            let got = loader
                .next()
                .unwrap_or_else(|| {
                    panic!("{shards} shard(s): ended at step {step}")
                })
                .unwrap();
            assert_eq!(got.block_ids, want.block_ids,
                       "{shards} shard(s), step {step}");
            assert_eq!(got.feats, want.feats,
                       "{shards} shard(s), step {step}");
            assert_eq!(got.labels, want.labels,
                       "{shards} shard(s), step {step}");
            assert_eq!(got.frame_mask, want.frame_mask,
                       "{shards} shard(s), step {step}");
            assert_eq!(got.seg_ids, want.seg_ids,
                       "{shards} shard(s), step {step}");
        }
        assert!(loader.next().is_none(), "{shards} shard(s)");
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn zero_copy_knobs_replay_byte_identical() {
    // The zero-copy acceptance bar: every combination of the shard
    // read backend (pread / mmap) and the readahead window (off / on)
    // replays the exact batch sequence of the in-memory offline epoch.
    // The knobs may only move *where and when* bytes are read.
    use bload::dataset::shardstore::{ShardMode, ShardSetWriter};
    let cfg = ExperimentConfig::default_config();
    let dcfg = cfg.dataset.scaled(0.01);
    let gen_seed = 17u64;
    let ds = generate(&dcfg, gen_seed);

    let builder = DataLoaderBuilder::new()
        .batch(2)
        .workers(3)
        .depth(2)
        .seed(17);
    let packed = Arc::new(
        pack(by_name("bload").unwrap(), &ds.train, &cfg.packing, 17)
            .unwrap(),
    );
    let split = Arc::new(ds.train);
    let mut memory = builder
        .planned(Arc::clone(&split), Arc::clone(&packed), 0)
        .unwrap();
    let mut reference = Vec::new();
    while let Some(b) = memory.next() {
        reference.push(b.unwrap());
    }
    assert!(!reference.is_empty(), "epoch has steps");

    let dir = std::env::temp_dir().join(format!(
        "bload_zero_copy_e2e_{}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&dir).ok();
    ShardSetWriter::new(&dir, gen_seed, 3)
        .unwrap()
        .write(&split)
        .unwrap();

    for mode in [ShardMode::Pread, ShardMode::Mmap] {
        for readahead in [0usize, 3] {
            let tag = format!("{} readahead {readahead}", mode.as_str());
            let mut loader = builder
                .clone()
                .shard_mode(mode)
                .readahead(readahead)
                .shards(&dir, &dcfg, by_name("bload").unwrap(),
                        &cfg.packing, 0)
                .unwrap();
            assert_eq!(loader.steps(), Some(reference.len()), "{tag}");
            for (step, want) in reference.iter().enumerate() {
                let got = loader
                    .next()
                    .unwrap_or_else(|| {
                        panic!("{tag}: ended at step {step}")
                    })
                    .unwrap();
                assert_eq!(got.block_ids, want.block_ids,
                           "{tag}, step {step}");
                assert_eq!(got.feats, want.feats, "{tag}, step {step}");
                assert_eq!(got.labels, want.labels, "{tag}, step {step}");
                assert_eq!(got.frame_mask, want.frame_mask,
                           "{tag}, step {step}");
                assert_eq!(got.seg_ids, want.seg_ids,
                           "{tag}, step {step}");
            }
            assert!(loader.next().is_none(), "{tag}");
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn remote_replay_is_byte_identical_for_concurrent_clients() {
    // The net acceptance bar, extending
    // `shard_replay_is_byte_identical_for_any_shard_count` across the
    // wire: the same shard set fronted by a loopback `bload serve`
    // daemon delivers — to several *concurrent* client connections, each
    // with different worker/depth settings — the exact batch sequence of
    // the in-memory offline epoch.
    use bload::dataset::shardstore::{ShardPool, ShardSetWriter};
    use bload::net::Server;

    let cfg = ExperimentConfig::default_config();
    let dcfg = cfg.dataset.scaled(0.01);
    let gen_seed = 13u64;
    let ds = generate(&dcfg, gen_seed);

    let packed = Arc::new(
        pack(by_name("bload").unwrap(), &ds.train, &cfg.packing, 13)
            .unwrap(),
    );
    let split = Arc::new(ds.train);
    let mut memory = DataLoaderBuilder::new()
        .batch(2)
        .workers(3)
        .depth(2)
        .seed(13)
        .shard(2, 1)
        .planned(Arc::clone(&split), Arc::clone(&packed), 2)
        .unwrap();
    let mut reference = Vec::new();
    while let Some(b) = memory.next() {
        reference.push(b.unwrap());
    }
    assert!(!reference.is_empty(), "epoch has steps");

    let dir = std::env::temp_dir().join(format!(
        "bload_remote_replay_e2e_{}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&dir).ok();
    ShardSetWriter::new(&dir, gen_seed, 2)
        .unwrap()
        .write(&split)
        .unwrap();
    let mut scfg = cfg.serve.clone();
    scfg.addr = "127.0.0.1:0".into();
    let pool = Arc::new(ShardPool::open(&dir).unwrap());
    let server = Server::start(pool, &scfg).unwrap();
    let addr = server.addr().to_string();

    // Three clients share the daemon concurrently; worker count and
    // prefetch depth must not change delivered bytes.
    std::thread::scope(|s| {
        for &(workers, depth) in &[(1usize, 1usize), (3, 2), (2, 4)] {
            let addr = addr.clone();
            let dcfg = dcfg.clone();
            let pcfg = cfg.packing.clone();
            let reference = &reference;
            s.spawn(move || {
                let tag = format!("workers {workers} depth {depth}");
                let mut loader = DataLoaderBuilder::new()
                    .batch(2)
                    .workers(workers)
                    .depth(depth)
                    .seed(13)
                    .shard(2, 1)
                    .remote(&addr, &dcfg, by_name("bload").unwrap(),
                            &pcfg, 2)
                    .unwrap();
                assert_eq!(loader.steps(), Some(reference.len()), "{tag}");
                for (step, want) in reference.iter().enumerate() {
                    let got = loader
                        .next()
                        .unwrap_or_else(|| {
                            panic!("{tag}: ended at step {step}")
                        })
                        .unwrap();
                    assert_eq!(got.block_ids, want.block_ids,
                               "{tag}, step {step}");
                    assert_eq!(got.feats, want.feats, "{tag}, step {step}");
                    assert_eq!(got.labels, want.labels,
                               "{tag}, step {step}");
                    assert_eq!(got.frame_mask, want.frame_mask,
                               "{tag}, step {step}");
                    assert_eq!(got.seg_ids, want.seg_ids,
                               "{tag}, step {step}");
                }
                assert!(loader.next().is_none(), "{tag}");
            });
        }
    });

    let stats = server.stats();
    assert!(stats.connections >= 3, "three clients connected");
    server.shutdown().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn fleet_replay_is_byte_identical_across_striped_daemons() {
    // The fleet acceptance bar: one epoch striped across two loopback
    // daemons (both serving the same shard set) delivers the exact
    // batch sequence of the in-memory offline epoch, with the traffic
    // actually split between the hosts.
    use bload::dataset::shardstore::{ShardPool, ShardSetWriter};
    use bload::net::Server;

    let cfg = ExperimentConfig::default_config();
    let dcfg = cfg.dataset.scaled(0.01);
    let gen_seed = 13u64;
    let ds = generate(&dcfg, gen_seed);

    let packed = Arc::new(
        pack(by_name("bload").unwrap(), &ds.train, &cfg.packing, 13)
            .unwrap(),
    );
    let split = Arc::new(ds.train);
    let mut memory = DataLoaderBuilder::new()
        .batch(2)
        .workers(3)
        .depth(2)
        .seed(13)
        .planned(Arc::clone(&split), Arc::clone(&packed), 1)
        .unwrap();
    let mut reference = Vec::new();
    while let Some(b) = memory.next() {
        reference.push(b.unwrap());
    }
    assert!(!reference.is_empty(), "epoch has steps");

    let dir = std::env::temp_dir().join(format!(
        "bload_fleet_replay_e2e_{}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&dir).ok();
    ShardSetWriter::new(&dir, gen_seed, 2)
        .unwrap()
        .write(&split)
        .unwrap();
    let mut scfg = cfg.serve.clone();
    scfg.addr = "127.0.0.1:0".into();
    let pool = Arc::new(ShardPool::open(&dir).unwrap());
    let s1 = Server::start(Arc::clone(&pool), &scfg).unwrap();
    let s2 = Server::start(Arc::clone(&pool), &scfg).unwrap();
    let hosts = vec![s1.addr().to_string(), s2.addr().to_string()];

    let mut loader = DataLoaderBuilder::new()
        .batch(2)
        .workers(3)
        .depth(2)
        .seed(13)
        .fleet(&hosts, &dcfg, by_name("bload").unwrap(), &cfg.packing, 1)
        .unwrap();
    assert_eq!(loader.steps(), Some(reference.len()));
    for (step, want) in reference.iter().enumerate() {
        let got = loader
            .next()
            .unwrap_or_else(|| panic!("fleet epoch ended at step {step}"))
            .unwrap();
        assert_eq!(got.block_ids, want.block_ids, "step {step}");
        assert_eq!(got.feats, want.feats, "step {step}");
        assert_eq!(got.labels, want.labels, "step {step}");
        assert_eq!(got.frame_mask, want.frame_mask, "step {step}");
        assert_eq!(got.seg_ids, want.seg_ids, "step {step}");
    }
    assert!(loader.next().is_none());

    // The shard map really striped: each daemon served part of the set.
    assert!(s1.stats().requests > 0, "host 0 served nothing");
    assert!(s2.stats().requests > 0, "host 1 served nothing");
    s1.shutdown().unwrap();
    s2.shutdown().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn fleet_replay_survives_a_mid_epoch_primary_kill() {
    // Failover acceptance: two primaries plus one replica; one primary
    // dies *mid-epoch* and the epoch still completes byte-identical to
    // the in-memory plan — no duplicated or dropped frame — with the
    // dead host's stripe served by the replica.
    use std::time::Duration;

    use bload::config::FleetConfig;
    use bload::dataset::shardstore::{ShardPool, ShardSetWriter};
    use bload::net::{ClientConfig, Server};
    use bload::telemetry::{self, names};

    let cfg = ExperimentConfig::default_config();
    let dcfg = cfg.dataset.scaled(0.01);
    let gen_seed = 29u64;
    let ds = generate(&dcfg, gen_seed);

    let packed = Arc::new(
        pack(by_name("bload").unwrap(), &ds.train, &cfg.packing, 29)
            .unwrap(),
    );
    let split = Arc::new(ds.train);
    let mut memory = DataLoaderBuilder::new()
        .batch(2)
        .workers(2)
        .depth(2)
        .seed(29)
        .planned(Arc::clone(&split), Arc::clone(&packed), 0)
        .unwrap();
    let mut reference = Vec::new();
    while let Some(b) = memory.next() {
        reference.push(b.unwrap());
    }
    assert!(reference.len() >= 4, "need a mid-epoch to kill at");

    let dir = std::env::temp_dir().join(format!(
        "bload_fleet_failover_e2e_{}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&dir).ok();
    ShardSetWriter::new(&dir, gen_seed, 2)
        .unwrap()
        .write(&split)
        .unwrap();
    let mut scfg = cfg.serve.clone();
    scfg.addr = "127.0.0.1:0".into();
    let pool = Arc::new(ShardPool::open(&dir).unwrap());
    let s1 = Server::start(Arc::clone(&pool), &scfg).unwrap();
    let s2 = Server::start(Arc::clone(&pool), &scfg).unwrap();
    let replica = Server::start(Arc::clone(&pool), &scfg).unwrap();

    let mut fcfg = FleetConfig::with_hosts(vec![
        s1.addr().to_string(),
        s2.addr().to_string(),
    ]);
    fcfg.replicas = vec![replica.addr().to_string()];
    fcfg.health_interval = Duration::from_millis(200);
    let ccfg = ClientConfig {
        connect_timeout: Duration::from_millis(500),
        io_timeout: Duration::from_millis(500),
        retries: 1,
        backoff: Duration::from_millis(10),
    };

    // Counter deltas, not absolutes: telemetry is process-global and
    // other tests in this binary may run concurrently.
    let failovers_before =
        telemetry::snapshot().counter(names::FLEET_FAILOVERS);

    let mut loader = DataLoaderBuilder::new()
        .batch(2)
        .workers(2)
        .depth(2)
        .seed(29)
        .fleet_with(&fcfg, &ccfg, &dcfg, by_name("bload").unwrap(),
                    &cfg.packing, 0)
        .unwrap();
    assert_eq!(loader.steps(), Some(reference.len()));

    let mut s2 = Some(s2);
    for (step, want) in reference.iter().enumerate() {
        if step == 2 {
            // Kill primary 1 mid-epoch; its stripe must fail over.
            s2.take().unwrap().shutdown().unwrap();
        }
        let got = loader
            .next()
            .unwrap_or_else(|| panic!("epoch ended at step {step}"))
            .unwrap();
        assert_eq!(got.block_ids, want.block_ids, "step {step}");
        assert_eq!(got.feats, want.feats, "step {step}");
        assert_eq!(got.labels, want.labels, "step {step}");
        assert_eq!(got.frame_mask, want.frame_mask, "step {step}");
        assert_eq!(got.seg_ids, want.seg_ids, "step {step}");
    }
    assert!(loader.next().is_none());

    let failovers_after =
        telemetry::snapshot().counter(names::FLEET_FAILOVERS);
    assert!(
        failovers_after > failovers_before,
        "killing a primary mid-epoch must trigger failover \
         ({failovers_before} -> {failovers_after})"
    );
    assert!(replica.stats().requests > 0,
            "the replica picked up the dead primary's stripe");
    s1.shutdown().unwrap();
    replica.shutdown().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sampling_chunks_cover_prefixes_only() {
    // Each video's delivered frames are exactly frames [0, k*t_block).
    let dcfg = bload::harness::scaled_dataset(80, 10, 0.6);
    let pcfg = bload::harness::scaled_packing();
    let ds = generate(&dcfg, 5);
    let packed =
        pack_with_block_len(by_name("sampling").unwrap(), &ds.train, &pcfg,
                            24, 5)
            .unwrap();
    let mut covered: HashMap<u32, Vec<(usize, usize)>> = HashMap::new();
    for b in &packed.blocks {
        for s in &b.segments {
            covered
                .entry(s.video)
                .or_default()
                .push((s.src_start, s.src_start + s.len));
        }
    }
    let lens: HashMap<u32, usize> = ds
        .train
        .videos
        .iter()
        .map(|v| (v.id, v.len as usize))
        .collect();
    for (video, mut spans) in covered {
        spans.sort_unstable();
        // Contiguous from zero.
        let mut expect = 0usize;
        for (a, b) in &spans {
            assert_eq!(*a, expect, "video {video}");
            expect = *b;
        }
        let kept = expect;
        let vlen = lens[&video];
        assert_eq!(kept, vlen / 8 * 8, "video {video} len {vlen}");
    }
}
