//! Integration over the PJRT runtime: full training + evaluation through
//! the AOT'd artifacts. Skips (with a message) when artifacts are absent
//! so `cargo test` stays green before `make artifacts`.

use std::sync::Arc;

use bload::config::{EvalConfig, ExperimentConfig};
use bload::dataset::synthetic::generate;
use bload::harness::{scaled_dataset, scaled_packing};
use bload::packing::{by_name, pack_with_block_len};
use bload::runtime::{ArtifactManifest, Engine};
use bload::train::Trainer;

fn manifest() -> Option<ArtifactManifest> {
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(ArtifactManifest::load(dir).unwrap())
}

#[test]
fn two_epoch_training_reduces_loss_and_evaluates() {
    let Some(m) = manifest() else { return };
    let spec = m.profile("small").unwrap().clone();
    let dcfg = scaled_dataset(150, 40, 0.6);
    let pcfg = scaled_packing();
    let ds = generate(&dcfg, 0);
    let packed = Arc::new(
        pack_with_block_len(by_name("bload").unwrap(), &ds.train, &pcfg,
                            24, 0)
            .unwrap(),
    );
    let packed_test = Arc::new(
        pack_with_block_len(by_name("bload").unwrap(), &ds.test, &pcfg,
                            24, 1)
            .unwrap(),
    );
    let mut cfg = ExperimentConfig::default_config();
    cfg.ddp.ranks = 2;
    cfg.train.log_every = 0;
    let engine = Engine::load(spec).unwrap();
    let mut trainer = Trainer::new(engine, cfg.train.clone(),
                                   cfg.ddp.clone(), cfg.loader.clone(), 0)
        .unwrap();
    let train_split = Arc::new(ds.train);
    let test_split = Arc::new(ds.test);
    let e0 = trainer.train_epoch(&train_split, &packed, 0).unwrap();
    let e1 = trainer.train_epoch(&train_split, &packed, 1).unwrap();
    assert!(e1.mean_loss < e0.mean_loss,
            "loss should drop: {} -> {}", e0.mean_loss, e1.mean_loss);
    assert!(e0.real_frames > 0 && e0.slots >= e0.real_frames);
    let recall = trainer
        .evaluate(&test_split, &packed_test, &EvalConfig { recall_k: 20 })
        .unwrap();
    assert!((0.0..=100.0).contains(&recall));
    // Training should beat a random ranker's recall@20 over 156 candidates
    // (~13%) already after two epochs.
    assert!(recall > 15.0, "recall {recall}");
}

#[test]
fn ddp_gradients_match_single_rank_math() {
    // 2-rank DDP step with identical per-rank batches must equal a
    // single-rank step (mean of identical gradients == the gradient).
    let Some(m) = manifest() else { return };
    let spec = m.profile("tiny").unwrap().clone();
    let engine = Engine::load(spec.clone()).unwrap();
    let params = spec.load_init_params().unwrap();
    let (b, t, o, f, c) = (spec.batch, spec.block_len, spec.objects,
                           spec.feat_dim, spec.classes);
    let batch = bload::loader::DeviceBatch {
        feats: vec![0.25; b * t * o * f],
        labels: vec![1.0; b * t * o * c],
        frame_mask: vec![1.0; b * t],
        seg_ids: vec![0.0; b * t],
        block_ids: vec![0, 1],
        batch: b,
        block_len: t,
        objects: o,
        feat_dim: f,
        classes: c,
        real_frames: b * t,
        slots: b * t,
        pool: None,
    };
    let state = vec![0.0; b * spec.state_dim];
    let g = engine.grad_step(&params, &batch, &state).unwrap();
    let mut rank_grads = vec![g.grads.clone(), g.grads.clone()];
    let mut sync = bload::ddp::GradSynchronizer::new(
        Box::new(bload::ddp::RingAllReduce), 1 << 12);
    sync.sync(&mut rank_grads);
    for (a, b_) in rank_grads[0].iter().zip(&g.grads) {
        assert!((a - b_).abs() <= 1e-6 * b_.abs().max(1.0));
    }
}

#[test]
fn checkpoint_roundtrip_through_trainer_buffers() {
    let Some(m) = manifest() else { return };
    let spec = m.profile("tiny").unwrap().clone();
    let params = spec.load_init_params().unwrap();
    let mom = vec![0.5; params.len()];
    let path = std::env::temp_dir().join(format!(
        "bload_e2e_ckpt_{}.blck",
        std::process::id()
    ));
    bload::model::save_checkpoint(&path, 7, &params, &mom).unwrap();
    let ck = bload::model::load_checkpoint(&path).unwrap();
    assert_eq!(ck.step, 7);
    assert_eq!(ck.params, params);
    std::fs::remove_file(&path).ok();
}

#[test]
fn reset_table_blocks_cross_video_leakage_through_runtime() {
    // The end-to-end version of the kernel's segment-independence test:
    // perturbing video A's frames must not change video B's logits when
    // they share a block (seg ids distinct), and MUST change them when the
    // reset table is stripped (merged seg ids).
    let Some(m) = manifest() else { return };
    let spec = m.profile("tiny").unwrap().clone();
    let engine = Engine::load(spec.clone()).unwrap();
    let params = spec.load_init_params().unwrap();
    let (b, t, o, f, c) = (spec.batch, spec.block_len, spec.objects,
                           spec.feat_dim, spec.classes);
    let mk = |bump: f32, merged: bool| {
        let mut feats = vec![0.1; b * t * o * f];
        // Video A = slots [0, t/2), video B = rest (batch row 0).
        for slot in 0..t / 2 {
            for x in &mut feats[slot * o * f..(slot + 1) * o * f] {
                *x += bump;
            }
        }
        let seg_ids: Vec<f32> = (0..b * t)
            .map(|i| {
                let slot = i % t;
                if merged {
                    0.0
                } else if slot < t / 2 {
                    0.0
                } else {
                    1.0
                }
            })
            .collect();
        bload::loader::DeviceBatch {
            feats,
            labels: vec![0.0; b * t * o * c],
            frame_mask: vec![1.0; b * t],
            seg_ids,
            block_ids: vec![0, 1],
            batch: b,
            block_len: t,
            objects: o,
            feat_dim: f,
            classes: c,
            real_frames: b * t,
            slots: b * t,
            pool: None,
        }
    };
    let state = vec![0.0; b * spec.state_dim];
    let logits = |bump: f32, merged: bool| {
        engine
            .infer_step(&params, &mk(bump, merged), &state)
            .unwrap()
            .logits
    };
    let per_slot = o * c;
    let second_half = |l: &[f32]| l[(t / 2) * per_slot..t * per_slot].to_vec();

    // With reset table: B's logits identical under A-perturbation.
    let a = second_half(&logits(0.0, false));
    let b_ = second_half(&logits(3.0, false));
    let max_diff = a
        .iter()
        .zip(&b_)
        .map(|(x, y)| (x - y).abs())
        .fold(0f32, f32::max);
    assert!(max_diff < 1e-4, "leak across reset boundary: {max_diff}");

    // Without reset table (merged): perturbation must leak.
    let a = second_half(&logits(0.0, true));
    let b_ = second_half(&logits(3.0, true));
    let max_diff = a
        .iter()
        .zip(&b_)
        .map(|(x, y)| (x - y).abs())
        .fold(0f32, f32::max);
    assert!(max_diff > 1e-3, "merged ids should leak: {max_diff}");
}
