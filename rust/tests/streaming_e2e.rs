//! Integration: the streaming ingest subsystem end-to-end — disk shard →
//! StoreReader → bounded queue → windowed online BLoad → per-rank block
//! shards → streaming loader — against the offline pipeline's
//! guarantees. Composition only; per-module behaviour lives in unit
//! tests.

use std::sync::Arc;

use bload::config::ExperimentConfig;
use bload::dataset::store::{StoreReader, StoreWriter};
use bload::dataset::synthetic::generate;
use bload::ddp::sim;
use bload::harness::streaming::{self, StreamingOptions};
use bload::ingest::{self, IngestConfig};
use bload::loader::DataLoaderBuilder;
use bload::packing::{by_name, pack, Block};

#[test]
fn store_reader_feeds_service_and_prefetcher_delivers_every_frame() {
    let cfg = ExperimentConfig::default_config();
    let t_max = cfg.packing.t_max;
    let dcfg = cfg.dataset.scaled(0.02);
    let ds = generate(&dcfg, 3);
    let split = Arc::new(ds.train);

    // Persist the shard.
    let path = std::env::temp_dir().join(format!(
        "bload_stream_e2e_{}.blds",
        std::process::id()
    ));
    let mut w = StoreWriter::create(
        &path,
        3,
        (dcfg.objects as u32, dcfg.feat_dim as u32, dcfg.classes as u32),
        split.videos.len() as u32,
    )
    .unwrap();
    for v in &split.videos {
        w.append(&split.spec.materialize(*v)).unwrap();
    }
    w.finish().unwrap();

    // Service: single rank so coverage is exact (nothing dropped).
    let mut icfg = IngestConfig::new(t_max);
    icfg.online.window = 32;
    icfg.queue_cap = 16;
    let (mut svc, producer) = ingest::start(icfg).unwrap();

    // Feed straight off the disk shard, metadata-only.
    let feeder = {
        let path = path.clone();
        std::thread::spawn(move || {
            let mut r = StoreReader::open(&path).unwrap();
            while let Some(m) = r.next_meta() {
                producer.send(m.unwrap()).unwrap();
            }
        })
    };

    // Tee rank 0 into a streaming loader and keep the blocks.
    let rx = svc.take_output(0).unwrap();
    let (brx, tee) = ingest::tee_blocks(rx, 16);
    let mut loader = DataLoaderBuilder::new()
        .batch(2)
        .workers(3)
        .depth(3)
        .stream(Arc::clone(&split), brx, t_max)
        .unwrap();
    let mut frames = 0usize;
    while let Some(b) = loader.next() {
        frames += b.unwrap().real_frames;
    }
    loader.shutdown();
    feeder.join().unwrap();
    let blocks = tee.join().unwrap();
    let stats = svc.join().unwrap();
    std::fs::remove_file(&path).ok();

    // Strict stream validation: every video placed exactly once.
    let summary = bload::packing::validate::validate_stream(
        blocks.iter(),
        &split,
        t_max,
    )
    .unwrap();
    assert_eq!(summary.frames_placed, split.total_frames());
    assert_eq!(frames, split.total_frames(), "prefetcher delivered all");
    assert_eq!(stats.dropped_blocks, 0);
    assert_eq!(stats.packing.received, split.videos.len());
}

#[test]
fn multi_rank_service_yields_deadlock_free_equal_schedules() {
    let cfg = ExperimentConfig::default_config();
    let dcfg = cfg.dataset.scaled(0.03);
    let ds = generate(&dcfg, 9);
    let split = Arc::new(ds.train);
    let ranks = 4usize;

    let mut icfg = IngestConfig::new(cfg.packing.t_max);
    icfg.online.window = 48;
    icfg.ranks = ranks;
    let (mut svc, producer) = ingest::start(icfg).unwrap();
    let feeder = {
        let metas = split.videos.clone();
        std::thread::spawn(move || {
            for m in metas {
                producer.send(m).unwrap();
            }
        })
    };
    let collectors: Vec<_> = (0..ranks)
        .map(|r| {
            let rx = svc.take_output(r).unwrap();
            std::thread::spawn(move || rx.iter().collect::<Vec<Block>>())
        })
        .collect();
    feeder.join().unwrap();
    let per_rank: Vec<Vec<Block>> =
        collectors.into_iter().map(|c| c.join().unwrap()).collect();
    let stats = svc.join().unwrap();

    let counts: Vec<usize> = per_rank.iter().map(Vec::len).collect();
    assert!(counts.windows(2).all(|w| w[0] == w[1]), "{counts:?}");
    assert!(counts[0] > 0, "stream too small to shard");

    // The packed schedule completes on the threaded barrier engine; a
    // deliberately skewed schedule (the raw-batching failure mode) does
    // not.
    let iters: Vec<u64> = counts
        .iter()
        .map(|&c| (c * cfg.packing.t_max) as u64)
        .collect();
    let report = sim::run(&iters, std::time::Duration::from_secs(2));
    assert!(report.completed, "{report:?}");
    let _ = stats;
}

#[test]
fn harness_scenario_matches_acceptance_criteria() {
    // The `bload ingest` scenario at the example's scale: invariants
    // validated inside run(), padding within 2x of offline, DDP clean.
    let r = streaming::run(&StreamingOptions::default()).unwrap();
    assert!(r.ddp_completed);
    assert!(
        r.ratio_factor() <= 2.0,
        "online padding ratio {:.4} vs offline {:.4}",
        r.online_ratio(),
        r.offline_ratio()
    );
    // Throughput path ran: rank 0 materialized real frames.
    assert!(r.frames_streamed > 0 && r.steps_rank0 > 0);
}

#[test]
fn online_vs_offline_padding_is_bounded_by_naive_across_windows() {
    let cfg = ExperimentConfig::default_config();
    let dcfg = cfg.dataset.scaled(0.05);
    let ds = generate(&dcfg, 1);
    let naive_slots = ds.train.videos.len() * cfg.packing.t_max;
    let naive_padding = naive_slots - ds.train.total_frames();
    let offline =
        pack(by_name("bload").unwrap(), &ds.train, &cfg.packing, 1)
            .unwrap();
    for window in [8usize, 64, 512] {
        let mut ocfg =
            bload::packing::online::OnlineConfig::new(cfg.packing.t_max);
        ocfg.window = window;
        let items = ds
            .train
            .videos
            .iter()
            .map(|v| (v.id, v.len as usize));
        let (_, stats) =
            bload::packing::online::pack_stream(items, ocfg, 1).unwrap();
        // Never worse than naive (structural), conserve every frame.
        assert!(stats.padding * naive_slots
            <= naive_padding * stats.total_slots);
        assert_eq!(stats.frames, ds.train.total_frames());
    }
    // Offline is the quality reference point; it must also beat naive.
    assert!(offline.stats.padding < naive_padding);
}
