#!/usr/bin/env bash
# Loopback assault smoke — the load-tester's own end-to-end gate, run
# by scripts/check.sh and CI's bench-smoke job:
#
#   1. pack a small shard set into a scratch directory,
#   2. serve it on an ephemeral loopback port (--addr-file handshake,
#      no bind race),
#   3. poll the daemon once from the outside (`bload top --remote
#      --snapshot` -> TOP_remote.json),
#   4. run a three-testcase scenario against it — byte-identity replay,
#      tail-latency SLO, padding budget — and gate on the exit code
#      (any evaluator failure is nonzero). The benchkit report lands in
#      ASSAULT_smoke.json for the artifact upload / baseline tooling.
set -euo pipefail
cd "$(dirname "$0")/.."

BIN=(cargo run --release --quiet --)
WORK=$(mktemp -d)
SERVE_PID=""
trap 'kill "${SERVE_PID:-0}" 2>/dev/null || true; rm -rf "$WORK"' EXIT

"${BIN[@]}" pack --scale 0.004 --shards 2 --out "$WORK/agshards"
"${BIN[@]}" serve --dir "$WORK/agshards" --addr 127.0.0.1:0 \
  --addr-file "$WORK/addr.txt" &
SERVE_PID=$!
for _ in $(seq 1 100); do
  [ -s "$WORK/addr.txt" ] && break
  sleep 0.1
done
[ -s "$WORK/addr.txt" ] || {
  echo "assault_smoke: serve daemon never wrote its address" >&2
  exit 1
}
ADDR=$(cat "$WORK/addr.txt")

cat > "$WORK/assault.toml" <<EOF
[assault]
name = ci-smoke
destinations = ["$ADDR", "$WORK/agshards"]

[assault.setting]
repeat = 4
concurrency = 8
timeout = 10s

[[assault.testcase]]
name = replay-identity
destination = @0
evaluator = byte-identity

[[assault.testcase]]
name = tail-latency
destination = @0
evaluator = latency-slo
slo = 5s

[[assault.testcase]]
name = padding-budget
destination = @1
evaluator = padding-budget
EOF

"${BIN[@]}" top --remote "$ADDR" --snapshot --out TOP_remote.json
"${BIN[@]}" assault --config "$WORK/assault.toml" \
  --json ASSAULT_smoke.json
