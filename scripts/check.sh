#!/usr/bin/env bash
# Pre-PR gate: run this (and get it green) before opening a PR.
#
#   scripts/check.sh
#
# Mirrors CI: formatting, lints as errors, rustdoc with warnings as
# errors (broken intra-doc links rot silently otherwise), the rustdoc
# examples as tests (`cargo test --doc` — the docs/ book and module
# docs promise these compile AND run), the markdown link check over
# README.md + docs/ (scripts/linkcheck.sh), compile-check
# of every non-test target (benches + examples don't build under `cargo
# test`), the full test suite, then the bench-smoke run CI's
# `bench-smoke` job performs — every registered suite at smoke geometry,
# report written to BENCH_smoke.json (compare against a recorded
# baseline with `bload bench --compare benches/baseline.json --report
# BENCH_smoke.json`), then the loopback assault smoke
# (scripts/assault_smoke.sh: shard set -> serve daemon -> three-testcase
# load scenario, gated on evaluator verdicts), and finally the fleet
# smoke (scripts/fleet_smoke.sh: shard set -> three daemons -> striped
# replay --verify, fleet:// assault, kill-one-primary re-verify).
# Runtime tests/suites that need AOT artifacts skip themselves when
# artifacts/manifest.json is absent, so the gate is self-contained.
set -euo pipefail
cd "$(dirname "$0")/.."

cargo fmt --check \
  && cargo clippy -- -D warnings \
  && RUSTDOCFLAGS="-D warnings" cargo doc --no-deps \
  && cargo test --doc \
  && scripts/linkcheck.sh \
  && cargo build --benches --examples \
  && cargo test -q \
  && cargo run --release -- bench --smoke --json BENCH_smoke.json \
  && scripts/assault_smoke.sh \
  && scripts/fleet_smoke.sh
