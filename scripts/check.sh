#!/usr/bin/env bash
# Pre-PR gate: run this (and get it green) before opening a PR.
#
#   scripts/check.sh
#
# Mirrors CI: formatting, lints as errors, rustdoc with warnings as
# errors (broken intra-doc links rot silently otherwise), compile-check
# of every non-test target (benches + examples don't build under `cargo
# test`), then the full test suite. Runtime tests that need AOT
# artifacts skip themselves when artifacts/manifest.json is absent, so
# the suite is self-contained.
set -euo pipefail
cd "$(dirname "$0")/.."

cargo fmt --check \
  && cargo clippy -- -D warnings \
  && RUSTDOCFLAGS="-D warnings" cargo doc --no-deps \
  && cargo build --benches --examples \
  && cargo test -q
