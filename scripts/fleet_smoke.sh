#!/usr/bin/env bash
# Loopback fleet smoke — the striped data plane's end-to-end gate, run
# by scripts/check.sh and CI's bench-smoke job:
#
#   1. pack a small shard set into a scratch directory,
#   2. serve it from THREE daemons (two primaries + one replica), each
#      publishing its ephemeral port through --addr-file (atomic
#      write+rename, no bind race),
#   3. replay one epoch striped across the primaries with --verify
#      (byte-identity against the in-memory offline run),
#   4. summarize every daemon's STATS in one frame (`bload top --fleet
#      --snapshot` -> TOP_fleet.json),
#   5. run a fleet:// assault testcase with the byte-identity evaluator
#      (FLEET_assault.json for the artifact upload),
#   6. kill -9 one primary and re-verify: the replica must pick up the
#      dead host's stripe and the epoch must stay byte-identical.
set -euo pipefail
cd "$(dirname "$0")/.."

BIN=(cargo run --release --quiet --)
WORK=$(mktemp -d)
PIDS=()
trap 'kill "${PIDS[@]:-0}" 2>/dev/null || true; rm -rf "$WORK"' EXIT

"${BIN[@]}" pack --scale 0.004 --shards 2 --out "$WORK/agshards"

ADDRS=()
for i in 0 1 2; do
  "${BIN[@]}" serve --dir "$WORK/agshards" --addr 127.0.0.1:0 \
    --addr-file "$WORK/addr$i.txt" &
  PIDS+=($!)
done
for i in 0 1 2; do
  for _ in $(seq 1 100); do
    [ -s "$WORK/addr$i.txt" ] && break
    sleep 0.1
  done
  [ -s "$WORK/addr$i.txt" ] || {
    echo "fleet_smoke: daemon $i never wrote its address" >&2
    exit 1
  }
  ADDRS+=("$(cat "$WORK/addr$i.txt")")
done

cat > "$WORK/fleet.toml" <<EOF
[fleet]
hosts = ["${ADDRS[0]}", "${ADDRS[1]}"]
replicas = ["${ADDRS[2]}"]
health_interval = 500ms

[assault]
name = fleet-smoke

[assault.setting]
repeat = 4
concurrency = 8
timeout = 10s

[[assault.testcase]]
name = fleet-identity
destination = "fleet://"
evaluator = byte-identity
EOF

# Striped epoch must be byte-identical to the in-memory offline run.
"${BIN[@]}" replay --config "$WORK/fleet.toml" --scale 0.004 --verify

# One STATS frame covering the whole fleet (primaries + replica).
"${BIN[@]}" top \
  --fleet "${ADDRS[0]},${ADDRS[1]},${ADDRS[2]}" \
  --snapshot --out TOP_fleet.json

# The fleet:// destination drives the same striped provider.
"${BIN[@]}" assault --config "$WORK/fleet.toml" --json FLEET_assault.json

# Kill one primary outright; the replica must cover its stripe and the
# epoch must STILL verify byte-identical.
kill -9 "${PIDS[0]}"
wait "${PIDS[0]}" 2>/dev/null || true
"${BIN[@]}" replay --config "$WORK/fleet.toml" --scale 0.004 --verify
echo "fleet_smoke: byte-identity held through primary loss"
