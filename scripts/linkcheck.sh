#!/usr/bin/env bash
# Markdown link check, no dependencies: every relative link target in
# README.md and docs/*.md must exist on disk. External links
# (http/https/mailto) are skipped — CI must not depend on the network —
# and pure-anchor links (#section) are skipped; a `FILE#anchor` target
# checks only FILE. Exits nonzero listing every broken link.
#
#   scripts/linkcheck.sh [FILE.md ...]     # default: README.md docs/*.md
set -euo pipefail
cd "$(dirname "$0")/.."

files=("$@")
if [ "${#files[@]}" -eq 0 ]; then
  files=(README.md docs/*.md)
fi

broken=0
for f in "${files[@]}"; do
  [ -f "$f" ] || { echo "linkcheck: no such file: $f" >&2; broken=1; continue; }
  dir=$(dirname "$f")
  # Inline links: capture the (...) target of every [text](target).
  # Good enough for this repo's markdown; code fences don't use the
  # [..](..) shape so false positives don't arise in practice.
  while IFS= read -r target; do
    case "$target" in
      http://*|https://*|mailto:*) continue ;;
      '#'*) continue ;;
    esac
    path="${target%%#*}"
    [ -n "$path" ] || continue
    case "$path" in
      /*) resolved="$path" ;;
      *)  resolved="$dir/$path" ;;
    esac
    if [ ! -e "$resolved" ]; then
      echo "linkcheck: $f -> $target (missing: $resolved)" >&2
      broken=1
    fi
  done < <(grep -o '\[[^][]*\]([^()[:space:]]*)' "$f" \
             | sed 's/.*(\(.*\))/\1/' || true)
done

if [ "$broken" -ne 0 ]; then
  echo "linkcheck: FAILED" >&2
  exit 1
fi
echo "linkcheck: OK (${#files[@]} file(s))"
