//! Offline stub of the `xla` (PJRT) bindings used by the `bload` crate.
//!
//! This environment has no XLA/PJRT shared library and no network access,
//! so the real bindings cannot be built. The coordinator crate only needs
//! the API *surface* to compile; every entry point that would touch a real
//! runtime returns [`Error`] instead. `PjRtClient::cpu()` fails first, so
//! callers gate cleanly: `Engine::load` reports "runtime unavailable" and
//! the runtime test-suite skips (it already skips when artifacts are
//! absent). Replace the `vendor/xla` path dependency with the real `xla`
//! crate to execute artifacts.

use std::fmt;

/// Error type mirroring the real bindings' `xla::Error`.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Result alias mirroring the real bindings.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: XLA/PJRT runtime unavailable (built against the offline \
         `vendor/xla` API stub; link the real xla crate to execute \
         artifacts)"
    ))
}

/// Element types of device buffers (only F32 is used by this crate).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
}

/// Host-side literal (stub: carries no data).
#[derive(Debug, Clone)]
pub struct Literal {
    _priv: (),
}

impl Literal {
    /// Scalar literal (stub value is never executed).
    pub fn scalar(_x: f32) -> Literal {
        Literal { _priv: () }
    }

    /// Shaped literal from raw bytes (stub: shape/data are discarded).
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        Ok(Literal { _priv: () })
    }

    /// Decompose a tuple literal.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }

    /// Copy out as a typed host vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }
}

/// Parsed HLO module proto.
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    /// Parse an HLO text file (stub: fails — nothing could execute it).
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// XLA computation wrapper.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// On-device buffer produced by an execution.
#[derive(Debug)]
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    /// Synchronously copy the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    /// Execute with the given arguments (stub: always fails).
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    /// Create a CPU client. The stub fails here, which is the single gate
    /// every execution path goes through first.
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation)
                   -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_creation_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("unavailable"), "{err}");
    }

    #[test]
    fn literal_construction_is_permitted() {
        // Literals are built on the host before any execution; the stub
        // accepts them so shape-checking code paths stay exercisable.
        let l = Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[2, 2],
            &[0u8; 16],
        )
        .unwrap();
        assert!(l.to_tuple().is_err());
        let _ = Literal::scalar(1.0);
    }
}
